//! The one Mem-AOP-GD training step (Algorithm 1, applied per layer),
//! implemented once on the `exec` row-shard primitives and adapted by
//! every surface (`AopEngine`, the MLP API, `NativeTrainer`, the serve
//! job path).
//!
//! The step is split in the same two phases the compiled HLO artifacts
//! execute, generalized to a whole layer graph:
//!
//! 1. [`fwd_score`] — row-sharded forward trace, head loss + output
//!    gradient, then a backward sweep computing, *per layer*: the memory
//!    folding `X̂/Ĝ` (lines 3-4), the policy scores, the exact bias
//!    gradient, and the chained gradient `G_i = G_{i+1} W_i^T ⊙ act'`
//!    (eq. (2a)) — all against the pre-update weights, so nothing in
//!    this phase depends on any selection;
//! 2. (between the phases) the caller owns the per-layer `out_K`
//!    decisions — [`select_layers`] draws them output-layer-first from
//!    one RNG stream, matching the historical single-layer stream;
//! 3. [`apply`] — per-layer AOP weight update (compaction or mask
//!    regime), exact bias update, memory retention (lines 8-9).
//!
//! Determinism contract (inherited from `exec` and asserted by
//! `rust/tests/exec.rs`): every float quantity is computed on the fixed
//! shard grid and reduced in fixed shard order, and selections are made
//! globally on the calling thread — so curves and weights are
//! bit-identical at every thread count, for every activation × policy ×
//! per-layer-K combination.

use crate::aop::policy::{self, Policy, Selection};
use crate::exec::{reduce, shard, Executor};
use crate::model::activations::Activation;
use crate::model::loss::correct_rows;
use crate::tensor::{ops, rng::Rng, Matrix};

use crate::train::graph::{Graph, GraphState};
use crate::train::layer::AopLayerConfig;

/// Phase-1 outputs for one layer.
pub struct LayerFwd {
    /// Folded `X̂ = m^X + √η X` (alg. lines 3-4).
    pub xhat: Matrix,
    /// Folded `Ĝ = m^G + √η G`.
    pub ghat: Matrix,
    /// Policy scores `‖X̂_(m)‖ ‖Ĝ_(m)‖`, length M.
    pub scores: Vec<f32>,
    /// Raw bias gradient (column sums of `G`, unscaled by η).
    pub db: Vec<f32>,
}

/// Phase-1 outputs for the whole graph (index = layer index).
pub struct GraphFwd {
    pub loss: f32,
    /// Train-batch argmax accuracy (1.0 for single-output regression).
    pub acc: f32,
    pub layers: Vec<LayerFwd>,
}

/// One full step's diagnostics.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub loss: f32,
    pub acc: f32,
    /// `‖Ŵ*‖_F` of the applied update across all layers
    /// (`sqrt(Σ_i ‖Ŵ*_i‖_F²)`).
    pub wstar_fro: f32,
    /// Total distinct outer products evaluated across layers.
    pub k_effective: usize,
    /// Distinct outer products evaluated per layer.
    pub layer_k: Vec<usize>,
}

/// Phase 1: forward trace + per-layer folding/scores/bias sums + the
/// backward gradient chain, all row-sharded on the executor's fixed
/// grid. Selections do not exist yet — everything here is computed from
/// the pre-update weights, which is what lets the caller own the policy
/// decision (and the HLO path mirror it artifact-for-artifact).
pub fn fwd_score(
    graph: &Graph,
    state: &GraphState,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    exec: &Executor,
) -> GraphFwd {
    let n = graph.layers.len();
    assert_eq!(state.layers.len(), n, "state layers vs graph layers");
    let m = x.rows();
    assert_eq!(
        x.cols(),
        graph.layers[0].fan_in(),
        "input dim vs first layer"
    );
    let plan = exec.plan(m);
    let se = eta.sqrt();

    // Forward trace: acts[i] = act_i(acts[i-1] W_i + b_i). The input
    // batch stays borrowed (never cloned), and pre-activations are not
    // retained — every activation's derivative is computed from its
    // output (`Activation::grad_from_output`), for relu bitwise the same
    // mask as the `z > 0` form.
    let mut acts: Vec<Matrix> = Vec::with_capacity(n);
    for (li, layer) in graph.layers.iter().enumerate() {
        let mut h = Matrix::zeros(m, layer.fan_out());
        {
            let prev: &Matrix = if li == 0 { x } else { &acts[li - 1] };
            let hb = shard::RowBlocks::of(&mut h, &plan);
            exec.run_each(&plan, |i, rows| {
                let mut blk = hb.lock(i);
                shard::forward_rows(prev, &layer.w, &layer.b, rows, &mut blk);
                layer.activation.apply_block(&mut blk);
            });
        }
        acts.push(h);
    }

    // Head loss + output gradient (+ integer accuracy counts),
    // row-sharded. With a non-identity head activation the loss sees
    // `h = act(z)`, so the head's G picks up the chain factor
    // `act'(h)` — identity heads (the flat engine, the MLP default)
    // skip the multiply entirely and keep their historical bits.
    let out = &acts[n - 1];
    let p_out = out.cols();
    assert_eq!(y.shape(), (m, p_out), "target shape");
    let act_out = graph.layers[n - 1].activation;
    let mut g = Matrix::zeros(m, p_out);
    let head_parts: Vec<(f32, usize)> = {
        let gb = shard::RowBlocks::of(&mut g, &plan);
        exec.map(&plan, |i, rows| {
            let ob = shard::rows_of(out, rows.clone());
            let lp = graph.loss.partial_loss(ob, y, rows.clone());
            let mut blk = gb.lock(i);
            graph.loss.grad_rows(ob, y, rows.clone(), m, &mut blk);
            if act_out != Activation::Identity {
                for (v, &h) in blk.iter_mut().zip(ob.iter()) {
                    *v *= act_out.grad_from_output(h);
                }
            }
            (lp, correct_rows(ob, y, rows))
        })
    };
    let loss = graph
        .loss
        .finish_loss(reduce::sum_f32(head_parts.iter().map(|(l, _)| *l)), m, p_out);
    let correct = reduce::sum_usize(head_parts.iter().map(|(_, c)| *c));
    let acc = correct as f32 / m as f32;

    // Backward sweep: per-layer fold/scores/db, then chain G down with
    // the pre-update weights (eq. (2a)).
    let mut infos: Vec<Option<LayerFwd>> = (0..n).map(|_| None).collect();
    for i in (0..n).rev() {
        let layer = &graph.layers[i];
        let xin: &Matrix = if i == 0 { x } else { &acts[i - 1] };
        let mem = &state.layers[i].mem;
        // Exact selection never reads scores (`select_exact` takes every
        // row) — skip the per-row norm products for those layers
        let need_scores = state.layers[i].cfg.policy != Policy::Exact;
        let (nf, pf) = (layer.fan_in(), layer.fan_out());
        let mut xhat = Matrix::zeros(m, nf);
        let mut ghat = Matrix::zeros(m, pf);
        let mut scores = vec![0.0f32; m];
        let db_parts: Vec<Vec<f32>> = {
            let xh_blocks = shard::RowBlocks::of(&mut xhat, &plan);
            let gh_blocks = shard::RowBlocks::of(&mut ghat, &plan);
            let sc_blocks = shard::RowBlocks::of_slice(&mut scores, 1, &plan);
            exec.map(&plan, |si, rows| {
                let mut xh = xh_blocks.lock(si);
                let mut gh = gh_blocks.lock(si);
                if mem.enabled {
                    shard::fold_rows(xin, &mem.mem_x, se, rows.clone(), &mut xh);
                    shard::fold_rows(&g, &mem.mem_g, se, rows.clone(), &mut gh);
                } else {
                    shard::scale_rows(xin, se, rows.clone(), &mut xh);
                    shard::scale_rows(&g, se, rows.clone(), &mut gh);
                }
                if need_scores {
                    let mut sc = sc_blocks.lock(si);
                    shard::score_rows(&xh, &gh, nf, pf, &mut sc);
                }
                shard::col_sums_rows(shard::rows_of(&g, rows), pf)
            })
        };
        let db = reduce::sum_vecs(pf, db_parts.iter().map(|d| d.as_slice()));

        if i > 0 {
            // eq. (2a): G_i = G_{i+1} W_i^T ⊙ act'(h_{i-1}) — row-local,
            // so sharding is bitwise-free.
            let wt = layer.w.transpose();
            let act_prev = graph.layers[i - 1].activation;
            let h_prev = &acts[i - 1];
            let mut g_next = Matrix::zeros(m, nf);
            {
                let gn_blocks = shard::RowBlocks::of(&mut g_next, &plan);
                exec.run_each(&plan, |si, rows| {
                    let mut blk = gn_blocks.lock(si);
                    ops::matmul_rows(&g, &wt, rows.clone(), &mut blk);
                    let hb = shard::rows_of(h_prev, rows);
                    for (v, &h) in blk.iter_mut().zip(hb.iter()) {
                        *v *= act_prev.grad_from_output(h);
                    }
                });
            }
            g = g_next;
        }
        infos[i] = Some(LayerFwd {
            xhat,
            ghat,
            scores,
            db,
        });
    }
    GraphFwd {
        loss,
        acc,
        layers: infos
            .into_iter()
            .map(|i| i.expect("backward sweep visits every layer"))
            .collect(),
    }
}

/// Draw every layer's `out_K` decision from one RNG stream,
/// **output-layer-first** (the order the backward sweep produced the
/// scores in, and — for a single layer — exactly the historical
/// consumption pattern of the flat engine). This function is THE
/// definition of the draw order: every surface (engine, MLP,
/// experiment loop, serve jobs) consumes the stream through it, so the
/// bit-compatibility-critical invariant lives in one place. Returns
/// selections in layer order.
pub fn select_with_configs(
    cfgs: &[AopLayerConfig],
    scores: &[&[f32]],
    rng: &mut Rng,
) -> Vec<Selection> {
    let n = cfgs.len();
    assert_eq!(scores.len(), n, "one score vector per layer");
    let mut sels: Vec<Option<Selection>> = (0..n).map(|_| None).collect();
    for i in (0..n).rev() {
        let c = &cfgs[i];
        sels[i] = Some(policy::select(
            c.policy,
            scores[i],
            c.k.min(scores[i].len()),
            c.memory,
            rng,
        ));
    }
    sels.into_iter()
        .map(|s| s.expect("selection drawn for every layer"))
        .collect()
}

/// [`select_with_configs`] against a state's per-layer configs and a
/// phase-1 result's score vectors.
pub fn select_layers(state: &GraphState, fwd: &GraphFwd, rng: &mut Rng) -> Vec<Selection> {
    assert_eq!(fwd.layers.len(), state.layers.len());
    let cfgs: Vec<AopLayerConfig> = state.layers.iter().map(|l| l.cfg).collect();
    let scores: Vec<&[f32]> = fwd.layers.iter().map(|l| l.scores.as_slice()).collect();
    select_with_configs(&cfgs, &scores, rng)
}

/// One layer's AOP weight gradient `Ŵ*_i` from its selection, sharded:
/// each shard accumulates the outer products of its own selected rows
/// (compaction regime) or its full masked row range (mask regime), and
/// the partials reduce in fixed shard order.
pub fn aop_weight_grad(
    lf: &LayerFwd,
    sel: &Selection,
    compact: bool,
    exec: &Executor,
) -> Matrix {
    let (m, nf) = lf.xhat.shape();
    let pf = lf.ghat.cols();
    let plan = exec.plan(m);
    let partials: Vec<Option<Matrix>> = if compact {
        let pairs = sel.compact_pairs();
        exec.map(&plan, |_, rows| {
            // `pairs` is ascending (Selection contract), so the filtered
            // slice keeps row order within the shard
            let local: Vec<(usize, f32)> = pairs
                .iter()
                .copied()
                .filter(|(r, _)| rows.contains(r))
                .collect();
            if local.is_empty() {
                None
            } else {
                Some(ops::masked_outer_compact(&lf.xhat, &lf.ghat, &local))
            }
        })
    } else {
        exec.map(&plan, |_, rows| {
            Some(ops::masked_outer_range(
                &lf.xhat,
                &lf.ghat,
                &sel.sel_scale,
                rows,
            ))
        })
    };
    reduce::sum_matrices(nf, pf, partials)
}

/// Phase 2: apply the per-layer selections — AOP weight update, exact
/// bias update `b -= η Σ_m G_(m)`, memory retention of the unselected
/// rows. Layers are independent here (the backward chain already ran in
/// phase 1 against pre-update weights), so updates land in place.
pub fn apply(
    graph: &mut Graph,
    state: &mut GraphState,
    fwd: &GraphFwd,
    sels: &[Selection],
    eta: f32,
    exec: &Executor,
    compact: bool,
) -> StepOutcome {
    let n = graph.layers.len();
    assert_eq!(sels.len(), n, "one selection per layer");
    assert_eq!(fwd.layers.len(), n);
    let m = fwd.layers[0].xhat.rows();
    let plan = exec.plan(m);
    let mut fro_sq = 0.0f64;
    let mut layer_k = Vec::with_capacity(n);
    for i in 0..n {
        let lf = &fwd.layers[i];
        let sel = &sels[i];
        let wstar = aop_weight_grad(lf, sel, compact, exec);
        fro_sq += (wstar.frobenius() as f64).powi(2);
        let layer = &mut graph.layers[i];
        layer.w.axpy(-1.0, &wstar);
        for (b, d) in layer.b.iter_mut().zip(lf.db.iter()) {
            *b -= eta * d;
        }
        let mem = &mut state.layers[i].mem;
        if mem.enabled {
            let mx_blocks = shard::RowBlocks::of(&mut mem.mem_x, &plan);
            let mg_blocks = shard::RowBlocks::of(&mut mem.mem_g, &plan);
            exec.run_each(&plan, |si, rows| {
                let mut mx = mx_blocks.lock(si);
                shard::keep_rows(&lf.xhat, &sel.keep, rows.clone(), &mut mx);
                let mut mg = mg_blocks.lock(si);
                shard::keep_rows(&lf.ghat, &sel.keep, rows, &mut mg);
            });
        }
        layer_k.push(sel.k_effective());
    }
    StepOutcome {
        loss: fwd.loss,
        acc: fwd.acc,
        wstar_fro: fro_sq.sqrt() as f32,
        k_effective: layer_k.iter().sum(),
        layer_k,
    }
}

/// Full Algorithm-1 step: `fwd_score → out_K per layer → apply`.
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    graph: &mut Graph,
    state: &mut GraphState,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    rng: &mut Rng,
    exec: &Executor,
    compact: bool,
) -> StepOutcome {
    let fwd = fwd_score(graph, state, x, y, eta, exec);
    let sels = select_layers(state, &fwd, rng);
    apply(graph, state, &fwd, &sels, eta, exec, compact)
}

/// Exact back-propagation (plain SGD) through the very same step: every
/// row selected deterministically, memories disabled (and — unlike the
/// old `train_step_sgd` hack — no throwaway memory matrices and no dummy
/// RNG are ever constructed).
pub fn train_step_exact(
    graph: &mut Graph,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    exec: &Executor,
) -> StepOutcome {
    let m = x.rows();
    let mut state = GraphState::exact(graph, m);
    let fwd = fwd_score(graph, &state, x, y, eta, exec);
    let sels: Vec<Selection> = (0..graph.layers.len())
        .map(|_| policy::select_exact(m))
        .collect();
    apply(graph, &mut state, &fwd, &sels, eta, exec, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::model::activations::Activation;
    use crate::model::loss::LossKind;
    use crate::tensor::ops;
    use crate::train::layer::AopLayerConfig;

    fn toy_data(rng: &mut Rng, b: usize, nin: usize, nout: usize) -> (Matrix, Matrix) {
        let x = Matrix::from_fn(b, nin, |_, _| rng.normal());
        let y = Matrix::from_fn(b, nout, |r, c| ((r % nout) == c) as u32 as f32);
        (x, y)
    }

    #[test]
    fn sgd_step_reduces_loss_on_fixed_batch() {
        let mut rng = Rng::new(2);
        let mut g = Graph::relu_mlp(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 12, 6, 3);
        let exec = Executor::serial();
        let before = g.evaluate(&x, &y).0;
        for _ in 0..30 {
            train_step_exact(&mut g, &x, &y, 0.1, &exec);
        }
        let after = g.evaluate(&x, &y).0;
        assert!(after < before * 0.7, "before={before} after={after}");
    }

    #[test]
    fn aop_topk_step_reduces_loss() {
        let mut rng = Rng::new(3);
        let mut g = Graph::relu_mlp(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 16, 6, 3);
        let mut state = GraphState::uniform(&g, 16, Policy::TopK, 4, true);
        let exec = Executor::serial();
        let before = g.evaluate(&x, &y).0;
        for _ in 0..60 {
            train_step(&mut g, &mut state, &x, &y, 0.1, &mut rng, &exec, true);
        }
        let after = g.evaluate(&x, &y).0;
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    #[test]
    fn exact_policy_is_sgd() {
        // AOP with the Exact policy must equal the plain SGD step exactly
        // (they are literally the same code path now).
        let mut rng = Rng::new(4);
        let g0 = Graph::relu_mlp(&mut rng, &[5, 8, 2], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 10, 5, 2);
        let exec = Executor::serial();

        let mut a = g0.clone();
        train_step_exact(&mut a, &x, &y, 0.05, &exec);

        let mut b = g0.clone();
        let mut state = GraphState::exact(&b, 10);
        let mut r2 = Rng::new(99);
        train_step(&mut b, &mut state, &x, &y, 0.05, &mut r2, &exec, true);

        for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(la.w.data(), lb.w.data());
            assert_eq!(la.b, lb.b);
        }
    }

    #[test]
    fn k_effective_counts_selected_products_per_layer() {
        let mut rng = Rng::new(5);
        let mut g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 8, 4, 2);
        let cfgs = [
            AopLayerConfig { k: 3, policy: Policy::TopK, memory: true },
            AopLayerConfig { k: 5, policy: Policy::TopK, memory: true },
        ];
        let mut state = GraphState::from_configs(&g, 8, &cfgs);
        let exec = Executor::serial();
        let out = train_step(&mut g, &mut state, &x, &y, 0.05, &mut rng, &exec, true);
        assert_eq!(out.layer_k, vec![3, 5]);
        assert_eq!(out.k_effective, 8);
    }

    #[test]
    fn single_layer_mse_matches_manual_gradient() {
        // one linear layer + MSE: W* = η X^T G exactly
        let mut rng = Rng::new(6);
        let mut g = Graph::relu_mlp(&mut rng, &[3, 2], LossKind::Mse);
        assert_eq!(g.layers[0].activation, Activation::Identity);
        let x = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(4, 2, |_, _| rng.normal());
        let w0 = g.layers[0].w.clone();
        let o = g.forward(&x);
        let (_, grad) = LossKind::Mse.loss_and_grad(&o, &y);
        let eta = 0.1f32;
        train_step_exact(&mut g, &x, &y, eta, &Executor::serial());
        let expect = w0.sub(&ops::matmul_tn(&x, &grad).scale(eta));
        assert!(g.layers[0].w.max_abs_diff(&expect) < 1e-5);
    }

    #[test]
    fn tanh_and_sigmoid_graphs_train() {
        for act in [Activation::Tanh, Activation::Sigmoid] {
            let mut rng = Rng::new(7);
            let mut g = Graph::relu_mlp(&mut rng, &[6, 10, 3], LossKind::SoftmaxCrossEntropy);
            g.layers[0].activation = act;
            let (x, y) = toy_data(&mut rng, 16, 6, 3);
            let mut state = GraphState::uniform(&g, 16, Policy::TopK, 6, true);
            let exec = Executor::serial();
            let before = g.evaluate(&x, &y).0;
            for _ in 0..80 {
                train_step(&mut g, &mut state, &x, &y, 0.2, &mut rng, &exec, true);
            }
            let after = g.evaluate(&x, &y).0;
            assert!(after < before, "{act:?}: before={before} after={after}");
            assert!(g.layers.iter().all(|l| l.w.is_finite()), "{act:?}");
        }
    }

    #[test]
    fn tanh_backward_matches_numeric_gradient() {
        // exact-policy step == SGD, so the applied update must match the
        // finite-difference loss gradient through the tanh hidden layer
        let mut rng = Rng::new(8);
        let mut g = Graph::relu_mlp(&mut rng, &[3, 5, 2], LossKind::Mse);
        g.layers[0].activation = Activation::Tanh;
        let x = Matrix::from_fn(6, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(6, 2, |_, _| rng.normal());
        let w0 = g.layers[0].w.clone();
        let loss_at = |gr: &Graph| gr.loss.loss(&gr.forward(&x), &y);
        let eps = 1e-3f32;
        let mut num_grad = vec![0.0f32; 4];
        let probes = [(0usize, 0usize), (1, 2), (2, 4), (0, 3)];
        for (pi, &(r, c)) in probes.iter().enumerate() {
            let mut gp = g.clone();
            gp.layers[0].w[(r, c)] += eps;
            let mut gm = g.clone();
            gm.layers[0].w[(r, c)] -= eps;
            num_grad[pi] = (loss_at(&gp) - loss_at(&gm)) / (2.0 * eps);
        }
        let eta = 0.05f32;
        train_step_exact(&mut g, &x, &y, eta, &Executor::serial());
        for (pi, &(r, c)) in probes.iter().enumerate() {
            let applied = (w0[(r, c)] - g.layers[0].w[(r, c)]) / eta;
            assert!(
                (applied - num_grad[pi]).abs() < 2e-2,
                "({r},{c}): applied {applied} vs numeric {}",
                num_grad[pi]
            );
        }
    }

    #[test]
    fn non_identity_head_matches_numeric_gradient() {
        // a sigmoid *head* must pick up the act'(h) chain factor on the
        // loss gradient — at every layer, not just below the head
        let mut rng = Rng::new(10);
        let mut g = Graph::relu_mlp(&mut rng, &[3, 5, 2], LossKind::Mse);
        g.layers[0].activation = Activation::Tanh; // smooth everywhere
        g.layers[1].activation = Activation::Sigmoid;
        let x = Matrix::from_fn(6, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(6, 2, |_, _| rng.uniform());
        let loss_at = |gr: &Graph| gr.loss.loss(&gr.forward(&x), &y);
        let eps = 1e-3f32;
        // probe both the head's and the hidden layer's weights
        let probes = [(1usize, 0usize, 0usize), (1, 4, 1), (0, 0, 2), (0, 2, 3)];
        let mut num_grad = vec![0.0f32; probes.len()];
        for (pi, &(li, r, c)) in probes.iter().enumerate() {
            let mut gp = g.clone();
            gp.layers[li].w[(r, c)] += eps;
            let mut gm = g.clone();
            gm.layers[li].w[(r, c)] -= eps;
            num_grad[pi] = (loss_at(&gp) - loss_at(&gm)) / (2.0 * eps);
        }
        let w0: Vec<Matrix> = g.layers.iter().map(|l| l.w.clone()).collect();
        let eta = 0.05f32;
        train_step_exact(&mut g, &x, &y, eta, &Executor::serial());
        for (pi, &(li, r, c)) in probes.iter().enumerate() {
            let applied = (w0[li][(r, c)] - g.layers[li].w[(r, c)]) / eta;
            assert!(
                (applied - num_grad[pi]).abs() < 2e-2,
                "layer {li} ({r},{c}): applied {applied} vs numeric {}",
                num_grad[pi]
            );
        }
    }

    #[test]
    fn memory_defers_unselected_rows_per_layer() {
        let mut rng = Rng::new(9);
        let mut g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::Mse);
        let x = Matrix::from_fn(16, 4, |_, _| rng.normal());
        let y = Matrix::from_fn(16, 2, |_, _| rng.normal());
        let mut state = GraphState::uniform(&g, 16, Policy::TopK, 4, true);
        train_step(&mut g, &mut state, &x, &y, 0.05, &mut rng, &Executor::serial(), true);
        for ls in &state.layers {
            let nz = (0..16)
                .filter(|&r| ls.mem.mem_x.row(r).iter().any(|&v| v != 0.0))
                .count();
            assert_eq!(nz, 12, "12 unselected rows must sit in memory");
        }
        assert!(state.deferred_mass() > 0.0);
    }
}
