//! The one Mem-AOP-GD training step (Algorithm 1, applied per layer),
//! implemented once on the `exec` row-shard primitives and adapted by
//! every surface (`AopEngine`, the MLP API, `NativeTrainer`, the serve
//! job path).
//!
//! The step is split in the same two phases the compiled HLO artifacts
//! execute, generalized to a whole layer graph:
//!
//! 1. [`fwd_score`] — row-sharded forward trace, head loss + output
//!    gradient, then a backward sweep computing, *per layer*: the memory
//!    folding `X̂/Ĝ` (lines 3-4), the policy scores, the exact bias
//!    gradient, and the chained gradient `G_i = G_{i+1} W_i^T ⊙ act'`
//!    (eq. (2a)) — all against the pre-update weights, so nothing in
//!    this phase depends on any selection;
//! 2. (between the phases) the caller owns the per-layer `out_K`
//!    decisions — [`select_layers_ws`]/[`select_with_configs`] draw them
//!    output-layer-first from one RNG stream, matching the historical
//!    single-layer stream;
//! 3. [`apply`] — per-layer AOP weight update (compaction or mask
//!    regime), exact bias update, memory retention (lines 8-9).
//!
//! **Workspace residency (§Perf pass)**: every transient of the step —
//! trace, gradients, foldings, scores, shard partials, selections —
//! lives in a caller-owned [`GraphWorkspace`], so a steady-state step
//! performs zero heap allocations; narrow-shape matmuls read the
//! layer's cached `W^T` ([`Dense::w_t`](crate::train::Dense::w_t),
//! refreshed in place by [`apply`]) instead of re-transposing per
//! shard. The convenience wrappers ([`train_step`],
//! [`train_step_exact`]) build a throwaway workspace per call and are
//! bit-identical to the resident-workspace path — there is exactly one
//! implementation.
//!
//! **Mixed precision (§Mixed precision)**: the workspace's per-layer
//! [`LayerPrecision`](crate::tensor::quant::LayerPrecision) steers two
//! orthogonal knobs through the same step. *Quantized traces* — a
//! layer's forward still computes exact f32 activations (into the trace
//! buffer's staging matrix), but the codes the **backward** pass
//! re-reads (`X̂` folding and the `act'` chain factor) are stored
//! bf16/q8, encoded per shard row-block during the forward; the f32
//! trace mode is bitwise the seed path. *Widened accumulation* — the
//! score dots, bias column sums, and the fixed-order shard reductions
//! run with f64 or Kahan-compensated accumulators in the same 8-lane
//! loop shape; `AccumMode::F32` dispatches to the seed kernels
//! unchanged. Both knobs are pure functions of data and config — never
//! of thread count or shard position — so the determinism contract
//! below holds in every precision cell.
//!
//! Determinism contract (inherited from `exec` and asserted by
//! `rust/tests/exec.rs`): every float quantity is computed on the fixed
//! shard grid and reduced in fixed shard order, and selections are made
//! globally on the calling thread — so curves and weights are
//! bit-identical at every thread count, for every activation × policy ×
//! per-layer-K combination, whether the workspace is fresh or reused.

use crate::aop::flops;
use crate::aop::policy::{self, Policy, SelectScratch, Selection};
use crate::exec::plan::ShardPlan;
use crate::exec::{shard, Executor};
use crate::model::activations::Activation;
use crate::model::loss::correct_rows;
use crate::obs::{AuditLayerRecord, Phase};
use crate::tensor::quant::{self, AccumMode, TraceBuf, TraceMode, TraceRef};
use crate::tensor::{ops, rng::Rng, Matrix};

use crate::train::graph::{Graph, GraphState};
use crate::train::layer::AopLayerConfig;
use crate::train::workspace::GraphWorkspace;

/// One full step's diagnostics. Per-layer `k_effective` values live in
/// the workspace ([`GraphWorkspace::layer_k`]) so the outcome itself
/// stays allocation-free.
#[derive(Debug, Clone, Copy)]
pub struct StepOutcome {
    pub loss: f32,
    pub acc: f32,
    /// `‖Ŵ*‖_F` of the applied update across all layers
    /// (`sqrt(Σ_i ‖Ŵ*_i‖_F²)`).
    pub wstar_fro: f32,
    /// Total distinct outer products evaluated across layers.
    pub k_effective: usize,
}

/// Phase 1: forward trace + per-layer folding/scores/bias sums + the
/// backward gradient chain, all row-sharded on the executor's fixed
/// grid, written into the workspace. Selections do not exist yet —
/// everything here is computed from the pre-update weights, which is
/// what lets the caller own the policy decision (and the HLO path
/// mirror it artifact-for-artifact). Returns `(train loss, batch acc)`.
pub fn fwd_score(
    graph: &Graph,
    state: &GraphState,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    exec: &Executor,
    ws: &mut GraphWorkspace,
) -> (f32, f32) {
    let n = graph.layers.len();
    assert_eq!(state.layers.len(), n, "state layers vs graph layers");
    let m = x.rows();
    assert_eq!(
        x.cols(),
        graph.layers[0].fan_in(),
        "input dim vs first layer"
    );
    ws.ensure(graph, m);
    let plan = exec.plan(m);
    let n_shards = plan.len();
    debug_assert_eq!(n_shards, ws.n_shards, "plan vs workspace shard count");
    let se = eta.sqrt();
    // obs (ISSUE 6): timers read clocks but never feed execution, so
    // curves stay bit-identical with telemetry on or off; `start` is
    // None (no clock read) when disabled
    let t_fwd = ws.obs.start();

    // Forward trace: acts[i] = act_i(acts[i-1] W_i + b_i). The input
    // batch stays borrowed (never cloned), and pre-activations are not
    // retained — every activation's derivative is computed from its
    // output (`Activation::grad_from_output`), for relu bitwise the same
    // mask as the `z > 0` form.
    for (li, layer) in graph.layers.iter().enumerate() {
        // warm the transpose cache on the coordinator thread (so shards
        // never race the lazy first computation) — but only when the
        // narrow-B path will actually read it; a wide layer's cache
        // stays cold and costs nothing here or in `apply`'s refresh
        let w_t = layer.warmed_w_t();
        let (before, rest) = ws.acts.split_at_mut(li);
        // the next layer's forward always reads exact activations (the
        // paper's forward stays exact); quantization only changes what
        // the *backward* pass re-reads
        let prev: &Matrix = if li == 0 { x } else { before[li - 1].exact() };
        let fwd = |rows: std::ops::Range<usize>, blk: &mut [f32]| {
            match w_t {
                Some(t) => shard::forward_rows_bt(prev, &layer.w, t, &layer.b, rows, blk),
                None => shard::forward_rows(prev, &layer.w, &layer.b, rows, blk),
            }
            layer.activation.apply_block(blk);
        };
        match &mut rest[0] {
            TraceBuf::F32(h) => {
                let hb = shard::RowBlocks::of(h, &plan);
                exec.run_each(&plan, |i, rows| {
                    // SAFETY: run_each claims each shard index exactly once
                    let blk = unsafe { hb.block(i) };
                    fwd(rows, blk);
                });
            }
            // quantize-on-write: each shard encodes its own just-computed
            // exact rows — a pure per-row encode, so sharded and serial
            // encodes produce identical codes (determinism contract)
            TraceBuf::Bf16 { cols, codes, stage, .. } => {
                let cols = *cols;
                let hb = shard::RowBlocks::of(stage, &plan);
                let cb = shard::RowBlocks::of_slice(codes.as_mut_slice(), cols, &plan);
                exec.run_each(&plan, |i, rows| {
                    // SAFETY (×2): run_each claims each shard index
                    // exactly once, so each splitter hands out `i` once
                    let blk = unsafe { hb.block(i) };
                    fwd(rows, blk);
                    let cblk = unsafe { cb.block(i) };
                    shard::encode_trace_rows_bf16(blk, cblk);
                });
            }
            TraceBuf::Q8 { cols, steps, codes, stage, .. } => {
                let cols = *cols;
                let hb = shard::RowBlocks::of(stage, &plan);
                let sb = shard::RowBlocks::of_slice(steps.as_mut_slice(), 1, &plan);
                let cb = shard::RowBlocks::of_slice(codes.as_mut_slice(), cols, &plan);
                exec.run_each(&plan, |i, rows| {
                    // SAFETY (×3): run_each claims each shard index
                    // exactly once, so each splitter hands out `i` once
                    let blk = unsafe { hb.block(i) };
                    fwd(rows, blk);
                    let sblk = unsafe { sb.block(i) };
                    let cblk = unsafe { cb.block(i) };
                    shard::encode_trace_rows_q8(blk, cols, sblk, cblk);
                });
            }
        }
    }

    // Head loss + output gradient (+ integer accuracy counts),
    // row-sharded into workspace slots. With a non-identity head
    // activation the loss sees `h = act(z)`, so the head's G picks up
    // the chain factor `act'(h)` — identity heads (the flat engine, the
    // MLP default) skip the multiply entirely.
    // head trace is pinned f32 at workspace build, so `exact()` is the
    // matrix the forward just wrote (no staging indirection)
    let out = ws.acts[n - 1].exact();
    let p_out = out.cols();
    assert_eq!(y.shape(), (m, p_out), "target shape");
    let act_out = graph.layers[n - 1].activation;
    {
        let gb = shard::RowBlocks::of(&mut ws.grads[n - 1], &plan);
        let loss_parts = &ws.loss_parts;
        exec.run_each(&plan, |i, rows| {
            let ob = shard::rows_of(out, rows.clone());
            let lp = graph.loss.partial_loss(ob, y, rows.clone());
            // SAFETY: run_each claims each shard index exactly once
            let blk = unsafe { gb.block(i) };
            graph.loss.grad_rows(ob, y, rows.clone(), m, blk);
            if act_out != Activation::Identity {
                for (v, &h) in blk.iter_mut().zip(ob.iter()) {
                    *v *= act_out.grad_from_output(h);
                }
            }
            *loss_parts[i].lock().unwrap() = (lp, correct_rows(ob, y, rows));
        });
    }
    // fixed shard-order reduction of the head partials
    let mut loss_total = 0.0f32;
    let mut correct = 0usize;
    for slot in ws.loss_parts.iter().take(n_shards) {
        let (l, c) = *slot.lock().unwrap();
        loss_total += l;
        correct += c;
    }
    let loss = graph.loss.finish_loss(loss_total, m, p_out);
    let acc = correct as f32 / m as f32;
    ws.obs.finish(Phase::Fwd, t_fwd);
    let t_score = ws.obs.start();

    // Backward sweep: per-layer fold/scores/db, then chain G down with
    // the pre-update weights (eq. (2a)).
    let shard_rows = ShardPlan::with_granularity(n_shards, 1);
    let max_pf = ws.db_parts.cols();
    for i in (0..n).rev() {
        let layer = &graph.layers[i];
        let mem = &state.layers[i].mem;
        // Exact selection never reads scores (`select_exact` takes every
        // row) — skip the per-row norm products for those layers
        let need_scores = state.layers[i].cfg.policy != Policy::Exact;
        let (nf, pf) = (layer.fan_in(), layer.fan_out());
        let accum = ws.prec[i].accum;
        {
            // the X̂ folding reads the stored (possibly quantized) trace
            // — this dequant-on-read is the backward memory-traffic win;
            // the raw input batch is always an exact f32 view
            let xin: TraceRef<'_> = if i == 0 {
                TraceRef::F32(x)
            } else {
                ws.acts[i - 1].as_ref()
            };
            let g = &ws.grads[i];
            let xh_blocks = shard::RowBlocks::of(&mut ws.xhat[i], &plan);
            let gh_blocks = shard::RowBlocks::of(&mut ws.ghat[i], &plan);
            let sc_blocks = shard::RowBlocks::of_slice(&mut ws.scores[i], 1, &plan);
            let db_blocks = shard::RowBlocks::of_slice(ws.db_parts.data_mut(), max_pf, &shard_rows);
            exec.run_each(&plan, |si, rows| {
                // SAFETY (×4): run_each claims each shard index exactly
                // once, so every splitter hands out block `si` once
                let xh = unsafe { xh_blocks.block(si) };
                let gh = unsafe { gh_blocks.block(si) };
                if mem.enabled {
                    shard::fold_trace_rows(xin, &mem.mem_x, se, rows.clone(), xh);
                    shard::fold_rows(g, &mem.mem_g, se, rows.clone(), gh);
                } else {
                    shard::scale_trace_rows(xin, se, rows.clone(), xh);
                    shard::scale_rows(g, se, rows.clone(), gh);
                }
                if need_scores {
                    // SAFETY: same claim — run_each hands out `si` once
                    let sc = unsafe { sc_blocks.block(si) };
                    shard::score_rows_acc(xh, gh, nf, pf, sc, accum);
                }
                // SAFETY: same claim — run_each hands out `si` once
                let db_blk = unsafe { db_blocks.block(si) };
                shard::col_sums_rows_into_acc(shard::rows_of(g, rows), pf, &mut db_blk[..pf], accum);
            });
        }
        // reduce the bias-gradient partials in fixed shard order —
        // widened modes carry the cross-shard chain in f64/Kahan
        // (element-outer, shard-inner, same fixed order)
        {
            let db = &mut ws.db[i];
            match accum {
                AccumMode::F32 => {
                    db.fill(0.0);
                    for si in 0..n_shards {
                        for (d, &v) in db.iter_mut().zip(ws.db_parts.row(si)[..pf].iter()) {
                            *d += v;
                        }
                    }
                }
                AccumMode::F64 => {
                    for (e, d) in db.iter_mut().enumerate() {
                        let mut acc = 0.0f64;
                        for si in 0..n_shards {
                            acc += ws.db_parts[(si, e)] as f64;
                        }
                        *d = acc as f32;
                    }
                }
                AccumMode::Kahan => {
                    for (e, d) in db.iter_mut().enumerate() {
                        let (mut acc, mut comp) = (0.0f32, 0.0f32);
                        for si in 0..n_shards {
                            let y = ws.db_parts[(si, e)] - comp;
                            let t = acc + y;
                            comp = (t - acc) - y;
                            acc = t;
                        }
                        *d = acc;
                    }
                }
            }
        }

        if i > 0 {
            // eq. (2a): G_{i-1} = G_i W_i^T ⊙ act'(h_{i-1}) — row-local,
            // so sharding is bitwise-free. The cached w_t IS the matmul
            // operand here, and `w` itself is its transpose — so the
            // narrow-B path needs no extra transpose either. The act'
            // chain factor reads the *stored* trace (dequant-on-read for
            // quantized layers), like every other backward trace read.
            let w_t = layer.w_t();
            let act_prev = graph.layers[i - 1].activation;
            let h_prev = ws.acts[i - 1].as_ref();
            let (gl, gr) = ws.grads.split_at_mut(i);
            let g_cur = &gr[0];
            let gn_blocks = shard::RowBlocks::of(&mut gl[i - 1], &plan);
            exec.run_each(&plan, |si, rows| {
                // SAFETY: run_each claims each shard index exactly once
                let blk = unsafe { gn_blocks.block(si) };
                ops::matmul_rows_bt(g_cur, w_t, &layer.w, rows.clone(), blk);
                match h_prev {
                    TraceRef::F32(m) => {
                        let hb = shard::rows_of(m, rows);
                        for (v, &h) in blk.iter_mut().zip(hb.iter()) {
                            *v *= act_prev.grad_from_output(h);
                        }
                    }
                    TraceRef::Bf16 { cols, codes } => {
                        let cb = &codes[rows.start * cols..rows.end * cols];
                        for (v, &c) in blk.iter_mut().zip(cb.iter()) {
                            *v *= act_prev.grad_from_output(quant::bf16_decode(c));
                        }
                    }
                    TraceRef::Q8 { cols, steps, codes } => {
                        for (local, r) in rows.enumerate() {
                            let step = steps[r];
                            let crow = &codes[r * cols..(r + 1) * cols];
                            let vrow = &mut blk[local * cols..(local + 1) * cols];
                            for (v, &c) in vrow.iter_mut().zip(crow.iter()) {
                                *v *= act_prev.grad_from_output(quant::q8_decode(c, step));
                            }
                        }
                    }
                }
            });
        }
    }
    ws.obs.finish(Phase::Score, t_score);
    ws.fwd = Some((loss, acc));
    (loss, acc)
}

/// One layer's `out_K` draw — THE definition shared by the workspace
/// path and the experiment loop, so the bit-compatibility-critical
/// clamp (`k.min(m)`) and RNG consumption live in one place.
fn select_one_into(
    cfg: &AopLayerConfig,
    scores: &[f32],
    rng: &mut Rng,
    scratch: &mut SelectScratch,
    sel: &mut Selection,
) {
    policy::select_into(
        cfg.policy,
        scores,
        cfg.k.min(scores.len()),
        cfg.memory,
        rng,
        scratch,
        sel,
    );
}

/// Draw every layer's `out_K` decision from one RNG stream,
/// **output-layer-first** (the order the backward sweep produced the
/// scores in, and — for a single layer — exactly the historical
/// consumption pattern of the flat engine). Returns selections in layer
/// order. The workspace path ([`select_layers_ws`]) draws through the
/// same per-layer helper, so the two can never drift.
pub fn select_with_configs(
    cfgs: &[AopLayerConfig],
    scores: &[&[f32]],
    rng: &mut Rng,
) -> Vec<Selection> {
    let n = cfgs.len();
    assert_eq!(scores.len(), n, "one score vector per layer");
    let mut scratch = SelectScratch::new();
    let mut sels: Vec<Selection> = scores
        .iter()
        .map(|s| Selection::with_capacity(s.len()))
        // lint: allow(hot-path-alloc) trait-path wrapper: the zero-alloc step draws into workspace-owned selections via select_layers_ws
        .collect();
    for i in (0..n).rev() {
        select_one_into(&cfgs[i], scores[i], rng, &mut scratch, &mut sels[i]);
    }
    sels
}

/// [`select_with_configs`] against the workspace's score vectors and
/// reusable selections — zero allocations in steady state. Results land
/// in [`GraphWorkspace::selections`].
pub fn select_layers_ws(state: &GraphState, ws: &mut GraphWorkspace, rng: &mut Rng) {
    let n = state.layers.len();
    assert_eq!(ws.sels.len(), n, "workspace selections vs layers");
    let t_sel = ws.obs.start();
    for i in (0..n).rev() {
        select_one_into(
            &state.layers[i].cfg,
            &ws.scores[i],
            rng,
            &mut ws.scratch,
            &mut ws.sels[i],
        );
    }
    ws.obs.finish(Phase::Select, t_sel);
}

/// Phase 2: apply the per-layer selections — AOP weight update, exact
/// bias update `b -= η Σ_m G_(m)`, memory retention of the unselected
/// rows — all on workspace partial buffers. Layers are independent here
/// (the backward chain already ran in phase 1 against pre-update
/// weights), so updates land in place; each layer's `w_t` cache is
/// refreshed (in place) after its weight update.
pub fn apply(
    graph: &mut Graph,
    state: &mut GraphState,
    sels: &[Selection],
    eta: f32,
    exec: &Executor,
    compact: bool,
    ws: &mut GraphWorkspace,
) -> StepOutcome {
    let n = graph.layers.len();
    assert_eq!(sels.len(), n, "one selection per layer");
    let (loss, acc) = ws.fwd.take().expect("apply called without fwd_score");
    let m = ws.batch;
    let plan = exec.plan(m);
    debug_assert_eq!(plan.len(), ws.n_shards, "plan vs workspace shard count");
    let t_apply = ws.obs.start();
    let mut fro_sq = 0.0f64;
    let mut k_total = 0usize;
    ws.layer_k.clear();
    for i in 0..n {
        let layer = &mut graph.layers[i];
        let (nf, pf) = (layer.fan_in(), layer.fan_out());
        let sel = &sels[i];
        assert_eq!(sel.sel_scale.len(), m, "selection rows vs batch");
        reduce_wstar_into_ws(ws, i, sel, compact, exec);
        fro_sq += (ws.wstar[i].frobenius() as f64).powi(2);
        // weight update straight from the accumulation layout — no
        // transpose copy; per-element it is the same subtraction
        if ops::aop_transposed(nf, pf) {
            layer.w.sub_transposed(&ws.wstar[i]);
        } else {
            layer.w.axpy(-1.0, &ws.wstar[i]);
        }
        for (b, d) in layer.b.iter_mut().zip(ws.db[i].iter()) {
            *b -= eta * d;
        }
        layer.refresh_w_t();
        let mem = &mut state.layers[i].mem;
        if mem.enabled {
            let xhat = &ws.xhat[i];
            let ghat = &ws.ghat[i];
            let mx_blocks = shard::RowBlocks::of(&mut mem.mem_x, &plan);
            let mg_blocks = shard::RowBlocks::of(&mut mem.mem_g, &plan);
            exec.run_each(&plan, |si, rows| {
                // SAFETY (×2): run_each claims each shard index exactly once
                let mx = unsafe { mx_blocks.block(si) };
                shard::keep_rows(xhat, &sel.keep, rows.clone(), mx);
                let mg = unsafe { mg_blocks.block(si) };
                shard::keep_rows(ghat, &sel.keep, rows, mg);
            });
        }
        let k = sel.k_effective();
        ws.layer_k.push(k);
        k_total += k;
        // realized-budget counters — FLOPs computed only when enabled
        if ws.obs.enabled() {
            let bf = flops::aop_step(m, nf, pf, k).backward_only();
            ws.obs.record_layer(i, k, bf);
        }
    }
    ws.obs.finish(Phase::Apply, t_apply);
    ws.obs.record_step();
    StepOutcome {
        loss,
        acc,
        wstar_fro: fro_sq.sqrt() as f32,
        k_effective: k_total,
    }
}

/// Shard-dispatch + fixed-order reduction of one layer's `Ŵ*` into
/// `ws.wstar[li]` (in the layer's [`ops::aop_layout`]). THE single
/// definition of the bit-compatibility-critical reduction, shared by
/// [`apply`] and the optimizer path: per-shard partials land in the
/// workspace buffer, then sum in ascending shard order — and
/// compaction-regime shards with no selected rows are skipped, exactly
/// like the historical `Option<Matrix>::None` partials (whether a shard
/// contributes depends only on the selection, never on scheduling).
fn reduce_wstar_into_ws(
    ws: &mut GraphWorkspace,
    li: usize,
    sel: &Selection,
    compact: bool,
    exec: &Executor,
) {
    let (m, nf) = ws.xhat[li].shape();
    let pf = ws.ghat[li].cols();
    let plan = exec.plan(m);
    let n_shards = plan.len();
    let (la, lb) = ops::aop_layout(nf, pf);
    let shard_rows = ShardPlan::with_granularity(n_shards, 1);
    let t_disp = ws.obs.start();
    {
        let xhat = &ws.xhat[li];
        let ghat = &ws.ghat[li];
        let parts =
            shard::RowBlocks::of_slice(ws.wstar_parts[li].data_mut(), la * lb, &shard_rows);
        exec.run_each(&plan, |si, rows| {
            // SAFETY: run_each claims each shard index exactly once
            let blk = unsafe { parts.block(si) };
            if compact {
                ops::masked_outer_compact_range_into(
                    xhat,
                    ghat,
                    &sel.indices,
                    &sel.sel_scale,
                    rows,
                    blk,
                );
            } else {
                ops::masked_outer_range_into(xhat, ghat, &sel.sel_scale, rows, blk);
            }
        });
    }
    ws.obs.finish(Phase::Dispatch, t_disp);
    let t_red = ws.obs.start();
    {
        let accum = ws.prec[li].accum;
        let wstar = &mut ws.wstar[li];
        let parts = ws.wstar_parts[li].data();
        // whether a shard contributes depends only on the selection,
        // never on scheduling — shared by all three accumulation modes
        let use_part = |si: usize| {
            if !compact {
                return true;
            }
            let rows = plan.range(si);
            let lo = sel.indices.partition_point(|&r| r < rows.start);
            let hi = sel.indices.partition_point(|&r| r < rows.end);
            lo != hi
        };
        match accum {
            AccumMode::F32 => {
                wstar.data_mut().fill(0.0);
                for si in 0..n_shards {
                    if !use_part(si) {
                        continue;
                    }
                    let part = &parts[si * la * lb..(si + 1) * la * lb];
                    for (o, &v) in wstar.data_mut().iter_mut().zip(part.iter()) {
                        *o += v;
                    }
                }
            }
            // widened carry across the shard chain, same ascending order
            AccumMode::F64 => ops::sum_parts_f64(wstar.data_mut(), parts, la * lb, use_part),
            AccumMode::Kahan => ops::sum_parts_kahan(wstar.data_mut(), parts, la * lb, use_part),
        }
    }
    ws.obs.finish(Phase::Reduce, t_red);
}

/// Gradient-fidelity audit (ISSUE 7 tentpole): measure the update
/// [`apply`] just made against the exact same-mini-batch gradient,
/// **without touching the run**. Must be called immediately after
/// [`apply`], while the step's buffers are still resident:
///
/// * `ws.wstar[li]` holds the applied approximate update — it is set
///   aside into audit scratch (nothing reads it again until the next
///   `apply`, which zeroes it first);
/// * `ws.xhat/ghat` still hold `fwd_score`'s memory-folded `X̂/Ĝ` —
///   re-running the fixed-order reduction with the deterministic K=M
///   selection ([`policy::select_exact_into`]: no RNG consumed) yields
///   the exact memory-corrected gradient the policy was subsampling;
/// * for memory-enabled layers, the dead `xhat/ghat` buffers are then
///   overwritten with the raw √η-scaled inputs and reduced once more,
///   giving the exact *raw* gradient — the distance between the two
///   exacts is how much the banked residual bends this step's gradient.
///
/// Per layer, `out` receives cosine similarity and relative Frobenius
/// error of approx-vs-exact plus that memory bias (f64 accumulation).
/// Under quantized traces (§Mixed precision) the resident `X̂` is first
/// corrected by the stored quantization residual, so the exact
/// reference is the **f32-trace** gradient and `rel_err` surfaces the
/// quantization drift itself (the `repro audit` fidelity read-out for
/// bf16/q8 runs); each record carries the input-trace mode it measured.
/// Observation-only contract: no RNG stream is consumed, no graph or
/// state value is written, only dead workspace buffers are clobbered —
/// audit-on curves are bit-identical to audit-off (asserted in
/// `rust/tests/exec.rs`) and steady-state audited steps allocate
/// nothing once the audit scratch exists (BENCH_8). Timed under
/// [`Phase::Audit`]; results are also recorded into the telemetry's
/// per-layer last-audit slots for job-view rollups.
#[allow(clippy::too_many_arguments)]
pub fn audit_into(
    graph: &Graph,
    state: &GraphState,
    x: &Matrix,
    eta: f32,
    exec: &Executor,
    compact: bool,
    ws: &mut GraphWorkspace,
    out: &mut Vec<AuditLayerRecord>,
) {
    let n = graph.layers.len();
    assert_eq!(state.layers.len(), n, "state layers vs graph layers");
    assert_eq!(ws.layer_k.len(), n, "audit_into must follow a completed apply");
    let m = ws.batch;
    assert_eq!(x.rows(), m, "audit batch vs workspace key");
    let se = eta.sqrt();
    let plan = exec.plan(m);
    ws.ensure_audit();
    out.clear();
    let t_audit = ws.obs.start();
    // the K=M selection is deterministic: every row, unit scale, no RNG
    let mut sel = std::mem::replace(&mut ws.audit_sel, Selection::with_capacity(0));
    policy::select_exact_into(m, &mut sel);
    for li in 0..n {
        // set the applied update aside — wstar is dead until next apply
        ws.audit_approx[li].data_mut().copy_from_slice(ws.wstar[li].data());
        // the trace this layer's X̂ was folded from: the raw f32 input
        // batch for the first layer, the previous layer's stored trace
        // otherwise — reported on the record so quantized drift is
        // attributable
        let in_trace = if li == 0 { TraceMode::F32 } else { ws.acts[li - 1].mode() };
        // §Mixed precision: correct the resident X̂ to the f32-trace
        // reference in place — X̂ += √η·(stage − deq(codes)) — so the
        // exact gradient below is the one an f32-trace run would apply
        // and rel_err includes the quantization drift. The pre-step
        // memory is gone (retention overwrote it in `apply`), which is
        // why the residual is added rather than re-folding from scratch.
        // A strict no-op for f32 traces (all-f32 audits stay bitwise the
        // seed auditor); X̂ is dead after this audit (the next fwd_score
        // rewrites it), so the clobber is observation-safe.
        if in_trace != TraceMode::F32 {
            let tb = &ws.acts[li - 1];
            let exact = tb.exact();
            let tr = tb.as_ref();
            let xh_blocks = shard::RowBlocks::of(&mut ws.xhat[li], &plan);
            exec.run_each(&plan, |si, rows| {
                // SAFETY: run_each claims each shard index exactly once
                let xh = unsafe { xh_blocks.block(si) };
                shard::trace_residual_rows(exact, tr, se, rows, xh);
            });
        }
        // exact memory-corrected gradient from the (corrected) foldings.
        // Ĝ stays as the step computed it — the chained gradient through
        // the quantized act' factors — so the reference is exact along
        // the X̂ axis; disentangling the G-side chain would need a full
        // exact re-backprop (see ROADMAP).
        reduce_wstar_into_ws(ws, li, &sel, compact, exec);
        ws.audit_exact[li].data_mut().copy_from_slice(ws.wstar[li].data());
        let (cosine, rel_err) =
            cosine_and_rel_err(ws.audit_approx[li].data(), ws.audit_exact[li].data());
        // memory-off layers fold nothing: folded == raw, bias is 0 by
        // construction — skip the second reduction
        let mem_bias = if state.layers[li].mem.enabled {
            // raw re-fold reads the exact staging activations, matching
            // the f32-trace reference the corrected X̂ now carries
            let xin: &Matrix = if li == 0 { x } else { ws.acts[li - 1].exact() };
            let g = &ws.grads[li];
            let xh_blocks = shard::RowBlocks::of(&mut ws.xhat[li], &plan);
            let gh_blocks = shard::RowBlocks::of(&mut ws.ghat[li], &plan);
            exec.run_each(&plan, |si, rows| {
                // SAFETY (×2): run_each claims each shard index exactly once
                let xh = unsafe { xh_blocks.block(si) };
                shard::scale_rows(xin, se, rows.clone(), xh);
                let gh = unsafe { gh_blocks.block(si) };
                shard::scale_rows(g, se, rows, gh);
            });
            reduce_wstar_into_ws(ws, li, &sel, compact, exec);
            rel_norm_diff(ws.audit_exact[li].data(), ws.wstar[li].data())
        } else {
            0.0
        };
        ws.obs.record_audit(li, cosine, rel_err, mem_bias);
        out.push(AuditLayerRecord { layer: li, cosine, rel_err, mem_bias, trace: in_trace });
    }
    ws.audit_sel = sel;
    ws.obs.finish(Phase::Audit, t_audit);
}

/// Cosine similarity and relative Frobenius error of `approx` against
/// the `exact` reference, accumulated in f64. Degenerate conventions:
/// two zero vectors are identical (cosine 1, error 0); one zero vector
/// has cosine 0; a zero reference with a non-zero approx has infinite
/// relative error.
fn cosine_and_rel_err(approx: &[f32], exact: &[f32]) -> (f64, f64) {
    debug_assert_eq!(approx.len(), exact.len());
    let (mut dot, mut na, mut nb, mut dd) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for (&a, &e) in approx.iter().zip(exact.iter()) {
        let (a, e) = (a as f64, e as f64);
        dot += a * e;
        na += a * a;
        nb += e * e;
        let d = a - e;
        dd += d * d;
    }
    let cosine = if na > 0.0 && nb > 0.0 {
        dot / (na.sqrt() * nb.sqrt())
    } else if na == 0.0 && nb == 0.0 {
        1.0
    } else {
        0.0
    };
    let rel_err = if nb > 0.0 {
        dd.sqrt() / nb.sqrt()
    } else if dd == 0.0 {
        0.0
    } else {
        f64::INFINITY
    };
    (cosine, rel_err)
}

/// `‖a − b‖ / ‖b‖` in f64 (same degenerate conventions as
/// [`cosine_and_rel_err`]'s relative error).
fn rel_norm_diff(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let (mut nb, mut dd) = (0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b.iter()) {
        let y64 = y as f64;
        nb += y64 * y64;
        let d = x as f64 - y64;
        dd += d * d;
    }
    if nb > 0.0 {
        dd.sqrt() / nb.sqrt()
    } else if dd == 0.0 {
        0.0
    } else {
        f64::INFINITY
    }
}

/// One layer's reduced AOP weight gradient `Ŵ*` as an owned `n × p`
/// matrix, recomputed from the workspace's last `fwd_score` buffers —
/// the optimizer path (Remark 1), which hands the raw gradient to an
/// external optimizer instead of applying it. Allocates for the result;
/// not a steady-state step path.
pub fn aop_weight_grad_ws(
    ws: &mut GraphWorkspace,
    li: usize,
    sel: &Selection,
    compact: bool,
    exec: &Executor,
) -> Matrix {
    let nf = ws.xhat[li].cols();
    let pf = ws.ghat[li].cols();
    reduce_wstar_into_ws(ws, li, sel, compact, exec);
    if ops::aop_transposed(nf, pf) {
        ws.wstar[li].transpose()
    } else {
        // lint: allow(hot-path-alloc) optimizer path returns an owned gradient by contract (see doc comment); the steady-state step applies in place
        ws.wstar[li].clone()
    }
}

/// Full Algorithm-1 step on a caller-owned workspace: `fwd_score →
/// out_K per layer → apply`. Zero heap allocations in steady state.
#[allow(clippy::too_many_arguments)]
pub fn train_step_ws(
    graph: &mut Graph,
    state: &mut GraphState,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    rng: &mut Rng,
    exec: &Executor,
    compact: bool,
    ws: &mut GraphWorkspace,
) -> StepOutcome {
    fwd_score(graph, state, x, y, eta, exec, ws);
    select_layers_ws(state, ws, rng);
    let sels = ws.take_sels();
    let out = apply(graph, state, &sels, eta, exec, compact, ws);
    ws.put_sels(sels);
    out
}

/// [`train_step_ws`] with a throwaway workspace — the convenience form
/// for tests and one-off steps (bit-identical; it is the same code).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    graph: &mut Graph,
    state: &mut GraphState,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    rng: &mut Rng,
    exec: &Executor,
    compact: bool,
) -> StepOutcome {
    let mut ws = GraphWorkspace::new(graph, x.rows());
    train_step_ws(graph, state, x, y, eta, rng, exec, compact, &mut ws)
}

/// Exact back-propagation (plain SGD) through the very same step on a
/// caller-owned workspace: every row selected deterministically,
/// memories disabled, no RNG consumed.
pub fn train_step_exact_ws(
    graph: &mut Graph,
    state: &mut GraphState,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    exec: &Executor,
    ws: &mut GraphWorkspace,
) -> StepOutcome {
    let m = x.rows();
    fwd_score(graph, state, x, y, eta, exec, ws);
    let mut sels = ws.take_sels();
    for sel in sels.iter_mut() {
        policy::select_exact_into(m, sel);
    }
    let out = apply(graph, state, &sels, eta, exec, true, ws);
    ws.put_sels(sels);
    out
}

/// Exact back-propagation with throwaway state + workspace — the
/// historical `train_step_sgd` surface (no memory matrices and no dummy
/// RNG are ever constructed).
pub fn train_step_exact(
    graph: &mut Graph,
    x: &Matrix,
    y: &Matrix,
    eta: f32,
    exec: &Executor,
) -> StepOutcome {
    let m = x.rows();
    let mut state = GraphState::exact(graph, m);
    let mut ws = GraphWorkspace::new(graph, m);
    train_step_exact_ws(graph, &mut state, x, y, eta, exec, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::model::activations::Activation;
    use crate::model::loss::LossKind;
    use crate::tensor::ops;
    use crate::train::layer::AopLayerConfig;

    fn toy_data(rng: &mut Rng, b: usize, nin: usize, nout: usize) -> (Matrix, Matrix) {
        let x = Matrix::from_fn(b, nin, |_, _| rng.normal());
        let y = Matrix::from_fn(b, nout, |r, c| ((r % nout) == c) as u32 as f32);
        (x, y)
    }

    #[test]
    fn sgd_step_reduces_loss_on_fixed_batch() {
        let mut rng = Rng::new(2);
        let mut g = Graph::relu_mlp(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 12, 6, 3);
        let exec = Executor::serial();
        let before = g.evaluate(&x, &y).0;
        for _ in 0..30 {
            train_step_exact(&mut g, &x, &y, 0.1, &exec);
        }
        let after = g.evaluate(&x, &y).0;
        assert!(after < before * 0.7, "before={before} after={after}");
    }

    #[test]
    fn aop_topk_step_reduces_loss() {
        let mut rng = Rng::new(3);
        let mut g = Graph::relu_mlp(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 16, 6, 3);
        let mut state = GraphState::uniform(&g, 16, Policy::TopK, 4, true);
        let exec = Executor::serial();
        let before = g.evaluate(&x, &y).0;
        for _ in 0..60 {
            train_step(&mut g, &mut state, &x, &y, 0.1, &mut rng, &exec, true);
        }
        let after = g.evaluate(&x, &y).0;
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    #[test]
    fn reused_workspace_is_bit_identical_to_fresh() {
        // the satellite guarantee at unit level: a workspace reused
        // across steps produces the same bits as a fresh one per step
        let mut mk = || {
            let mut rng = Rng::new(12);
            let g = Graph::relu_mlp(&mut rng, &[6, 9, 3], LossKind::Mse);
            let st = GraphState::uniform(&g, 16, Policy::WeightedK, 5, true);
            (g, st)
        };
        let mut rng = Rng::new(5);
        let (x, y) = toy_data(&mut rng, 16, 6, 3);
        let exec = Executor::serial();
        let (mut ga, mut sta) = mk();
        let (mut gb, mut stb) = mk();
        let mut ra = Rng::new(77);
        let mut rb = Rng::new(77);
        let mut ws = GraphWorkspace::new(&ga, 16);
        for _ in 0..12 {
            let a = train_step_ws(&mut ga, &mut sta, &x, &y, 0.05, &mut ra, &exec, true, &mut ws);
            let b = train_step(&mut gb, &mut stb, &x, &y, 0.05, &mut rb, &exec, true);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.wstar_fro.to_bits(), b.wstar_fro.to_bits());
        }
        for (la, lb) in ga.layers.iter().zip(gb.layers.iter()) {
            assert_eq!(la.w.data(), lb.w.data());
            assert_eq!(la.b, lb.b);
        }
    }

    #[test]
    fn obs_on_step_is_bit_identical_and_records_phases() {
        use crate::obs::ObsConfig;
        let mut mk = || {
            let mut rng = Rng::new(21);
            let g = Graph::relu_mlp(&mut rng, &[6, 9, 3], LossKind::Mse);
            let st = GraphState::uniform(&g, 16, Policy::TopK, 5, true);
            (g, st)
        };
        let mut rng = Rng::new(6);
        let (x, y) = toy_data(&mut rng, 16, 6, 3);
        let exec = Executor::serial();
        let (mut ga, mut sta) = mk();
        let (mut gb, mut stb) = mk();
        let mut ra = Rng::new(44);
        let mut rb = Rng::new(44);
        let mut wa = GraphWorkspace::with_obs(&ga, 16, ObsConfig::on());
        let mut wb = GraphWorkspace::new(&gb, 16);
        for _ in 0..5 {
            let a = train_step_ws(&mut ga, &mut sta, &x, &y, 0.05, &mut ra, &exec, true, &mut wa);
            let b = train_step_ws(&mut gb, &mut stb, &x, &y, 0.05, &mut rb, &exec, true, &mut wb);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.wstar_fro.to_bits(), b.wstar_fro.to_bits());
        }
        for (la, lb) in ga.layers.iter().zip(gb.layers.iter()) {
            assert_eq!(la.w.data(), lb.w.data(), "obs must never change the math");
            assert_eq!(la.b, lb.b);
        }
        let t = wa.obs();
        assert_eq!(t.steps(), 5);
        for p in [Phase::Fwd, Phase::Score, Phase::Select, Phase::Apply] {
            assert_eq!(t.phase(p).count(), 5, "{}", p.name());
        }
        // dispatch/reduce fire once per layer per step (nested in apply)
        assert_eq!(t.phase(Phase::Dispatch).count(), 10);
        assert_eq!(t.phase(Phase::Reduce).count(), 10);
        assert_eq!(t.layer_k_sum(), &[25, 25], "k=5 × 5 steps per layer");
        assert!(t.layer_flops().iter().all(|&f| f > 0));
        assert_eq!(t.trace().total(), 5 * (4 + 2 * 2) as u64);
        // and the obs-off workspace recorded nothing
        assert_eq!(wb.obs().steps(), 0);
        assert!(wb.obs().phase(Phase::Fwd).is_empty());
    }

    #[test]
    fn audit_of_exact_memory_off_step_is_perfect() {
        // K=M with no memory: the "approximate" update IS the exact
        // gradient, so the auditor must report zero error bit-for-bit
        let mut rng = Rng::new(13);
        let mut g = Graph::relu_mlp(&mut rng, &[5, 7, 2], LossKind::Mse);
        let (x, y) = toy_data(&mut rng, 16, 5, 2);
        let mut state = GraphState::exact(&g, 16);
        let exec = Executor::serial();
        let mut ws = GraphWorkspace::new(&g, 16);
        train_step_exact_ws(&mut g, &mut state, &x, &y, 0.05, &exec, &mut ws);
        let mut recs = Vec::new();
        audit_into(&g, &state, &x, 0.05, &exec, true, &mut ws, &mut recs);
        assert_eq!(recs.len(), 2);
        for r in &recs {
            assert_eq!(r.rel_err, 0.0, "layer {}: K=M is the exact gradient", r.layer);
            assert!((r.cosine - 1.0).abs() < 1e-12, "layer {} cosine {}", r.layer, r.cosine);
            assert_eq!(r.mem_bias, 0.0, "no memory ⇒ no bias");
        }
    }

    #[test]
    fn audit_is_observation_only_and_detects_memory_bias() {
        let mk = || {
            let mut rng = Rng::new(23);
            let g = Graph::relu_mlp(&mut rng, &[6, 9, 3], LossKind::Mse);
            let st = GraphState::uniform(&g, 16, Policy::TopK, 4, true);
            (g, st)
        };
        let mut rng = Rng::new(31);
        let (x, y) = toy_data(&mut rng, 16, 6, 3);
        let exec = Executor::serial();
        let (mut ga, mut sta) = mk();
        let (mut gb, mut stb) = mk();
        let mut ra = Rng::new(55);
        let mut rb = Rng::new(55);
        let mut wa = GraphWorkspace::new(&ga, 16);
        let mut wb = GraphWorkspace::new(&gb, 16);
        let mut recs = Vec::new();
        for step in 0..4 {
            let a = train_step_ws(&mut ga, &mut sta, &x, &y, 0.05, &mut ra, &exec, true, &mut wa);
            audit_into(&ga, &sta, &x, 0.05, &exec, true, &mut wa, &mut recs);
            let b = train_step_ws(&mut gb, &mut stb, &x, &y, 0.05, &mut rb, &exec, true, &mut wb);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
            assert_eq!(a.wstar_fro.to_bits(), b.wstar_fro.to_bits(), "step {step}");
            assert_eq!(recs.len(), 2);
            for r in &recs {
                assert!(
                    r.cosine.is_finite() && r.cosine.abs() <= 1.0 + 1e-9,
                    "cosine {}",
                    r.cosine
                );
                assert!(
                    r.rel_err.is_finite() && r.rel_err > 0.0,
                    "k=4 of m=16 must show approximation error, got {}",
                    r.rel_err
                );
                assert!(r.mem_bias.is_finite());
            }
            if step > 0 {
                // after one retention the banked residual must bend the
                // exact gradient somewhere
                assert!(recs.iter().any(|r| r.mem_bias > 0.0), "step {step}: {recs:?}");
            }
        }
        // the audited run's weights are bit-identical to the unaudited one
        for (la, lb) in ga.layers.iter().zip(gb.layers.iter()) {
            assert_eq!(la.w.data(), lb.w.data(), "audit must never change the math");
            assert_eq!(la.b, lb.b);
        }
    }

    #[test]
    fn quantized_traces_train_and_audit_reports_input_trace() {
        use crate::tensor::quant::{AccumMode, LayerPrecision, TraceMode};
        for trace in [TraceMode::Bf16, TraceMode::Q8] {
            let mut rng = Rng::new(33);
            let mut g = Graph::relu_mlp(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
            let (x, y) = toy_data(&mut rng, 16, 6, 3);
            let mut state = GraphState::uniform(&g, 16, Policy::TopK, 6, true);
            let exec = Executor::serial();
            let mut ws = GraphWorkspace::new(&g, 16);
            ws.set_precision(&g, &[LayerPrecision { trace, accum: AccumMode::F64 }; 2]);
            let before = g.evaluate(&x, &y).0;
            for _ in 0..60 {
                train_step_ws(&mut g, &mut state, &x, &y, 0.1, &mut rng, &exec, true, &mut ws);
            }
            let mut recs = Vec::new();
            audit_into(&g, &state, &x, 0.1, &exec, true, &mut ws, &mut recs);
            let after = g.evaluate(&x, &y).0;
            assert!(after < before * 0.8, "{trace:?}: before={before} after={after}");
            assert!(g.layers.iter().all(|l| l.w.is_finite()), "{trace:?}");
            // layer 0's X̂ comes from the raw f32 input batch; layer 1's
            // from the quantized hidden trace
            assert_eq!(recs[0].trace, TraceMode::F32);
            assert_eq!(recs[1].trace, trace);
            for r in &recs {
                assert!(
                    r.cosine > 0.9 && r.cosine.is_finite(),
                    "{trace:?} layer {} cosine {}",
                    r.layer,
                    r.cosine
                );
                assert!(r.rel_err.is_finite() && r.mem_bias.is_finite(), "{trace:?}");
            }
        }
    }

    #[test]
    fn f32_precision_knobs_are_bitwise_the_seed_step() {
        // explicit all-f32 precision (the default) through set_precision
        // must not perturb a single bit vs an untouched workspace
        use crate::tensor::quant::LayerPrecision;
        let mut mk = || {
            let mut rng = Rng::new(41);
            let g = Graph::relu_mlp(&mut rng, &[6, 9, 3], LossKind::Mse);
            let st = GraphState::uniform(&g, 16, Policy::WeightedK, 5, true);
            (g, st)
        };
        let mut rng = Rng::new(42);
        let (x, y) = toy_data(&mut rng, 16, 6, 3);
        let exec = Executor::serial();
        let (mut ga, mut sta) = mk();
        let (mut gb, mut stb) = mk();
        let mut ra = Rng::new(7);
        let mut rb = Rng::new(7);
        let mut wa = GraphWorkspace::new(&ga, 16);
        wa.set_precision(&ga, &[LayerPrecision::exact(); 2]);
        let mut wb = GraphWorkspace::new(&gb, 16);
        for _ in 0..8 {
            let a = train_step_ws(&mut ga, &mut sta, &x, &y, 0.05, &mut ra, &exec, true, &mut wa);
            let b = train_step_ws(&mut gb, &mut stb, &x, &y, 0.05, &mut rb, &exec, true, &mut wb);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.wstar_fro.to_bits(), b.wstar_fro.to_bits());
        }
        for (la, lb) in ga.layers.iter().zip(gb.layers.iter()) {
            assert_eq!(la.w.data(), lb.w.data());
            assert_eq!(la.b, lb.b);
        }
    }

    #[test]
    #[should_panic(expected = "completed apply")]
    fn audit_without_apply_panics() {
        let mut rng = Rng::new(14);
        let g = Graph::relu_mlp(&mut rng, &[4, 2], LossKind::Mse);
        let state = GraphState::exact(&g, 8);
        let mut ws = GraphWorkspace::new(&g, 8);
        let x = Matrix::from_fn(8, 4, |_, _| 0.5);
        let mut recs = Vec::new();
        audit_into(&g, &state, &x, 0.1, &Executor::serial(), true, &mut ws, &mut recs);
    }

    #[test]
    fn exact_policy_is_sgd() {
        // AOP with the Exact policy must equal the plain SGD step exactly
        // (they are literally the same code path now).
        let mut rng = Rng::new(4);
        let g0 = Graph::relu_mlp(&mut rng, &[5, 8, 2], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 10, 5, 2);
        let exec = Executor::serial();

        let mut a = g0.clone();
        train_step_exact(&mut a, &x, &y, 0.05, &exec);

        let mut b = g0.clone();
        let mut state = GraphState::exact(&b, 10);
        let mut r2 = Rng::new(99);
        train_step(&mut b, &mut state, &x, &y, 0.05, &mut r2, &exec, true);

        for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(la.w.data(), lb.w.data());
            assert_eq!(la.b, lb.b);
        }
    }

    #[test]
    fn k_effective_counts_selected_products_per_layer() {
        let mut rng = Rng::new(5);
        let mut g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 8, 4, 2);
        let cfgs = [
            AopLayerConfig { k: 3, policy: Policy::TopK, memory: true },
            AopLayerConfig { k: 5, policy: Policy::TopK, memory: true },
        ];
        let mut state = GraphState::from_configs(&g, 8, &cfgs);
        let exec = Executor::serial();
        let mut ws = GraphWorkspace::new(&g, 8);
        let out = train_step_ws(&mut g, &mut state, &x, &y, 0.05, &mut rng, &exec, true, &mut ws);
        assert_eq!(ws.layer_k(), &[3, 5]);
        assert_eq!(out.k_effective, 8);
    }

    #[test]
    fn single_layer_mse_matches_manual_gradient() {
        // one linear layer + MSE: W* = η X^T G exactly
        let mut rng = Rng::new(6);
        let mut g = Graph::relu_mlp(&mut rng, &[3, 2], LossKind::Mse);
        assert_eq!(g.layers[0].activation, Activation::Identity);
        let x = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(4, 2, |_, _| rng.normal());
        let w0 = g.layers[0].w.clone();
        let o = g.forward(&x);
        let (_, grad) = LossKind::Mse.loss_and_grad(&o, &y);
        let eta = 0.1f32;
        train_step_exact(&mut g, &x, &y, eta, &Executor::serial());
        let expect = w0.sub(&ops::matmul_tn(&x, &grad).scale(eta));
        assert!(g.layers[0].w.max_abs_diff(&expect) < 1e-5);
    }

    #[test]
    fn tanh_and_sigmoid_graphs_train() {
        for act in [Activation::Tanh, Activation::Sigmoid] {
            let mut rng = Rng::new(7);
            let mut g = Graph::relu_mlp(&mut rng, &[6, 10, 3], LossKind::SoftmaxCrossEntropy);
            g.layers[0].activation = act;
            let (x, y) = toy_data(&mut rng, 16, 6, 3);
            let mut state = GraphState::uniform(&g, 16, Policy::TopK, 6, true);
            let exec = Executor::serial();
            let before = g.evaluate(&x, &y).0;
            for _ in 0..80 {
                train_step(&mut g, &mut state, &x, &y, 0.2, &mut rng, &exec, true);
            }
            let after = g.evaluate(&x, &y).0;
            assert!(after < before, "{act:?}: before={before} after={after}");
            assert!(g.layers.iter().all(|l| l.w.is_finite()), "{act:?}");
        }
    }

    #[test]
    fn tanh_backward_matches_numeric_gradient() {
        // exact-policy step == SGD, so the applied update must match the
        // finite-difference loss gradient through the tanh hidden layer
        let mut rng = Rng::new(8);
        let mut g = Graph::relu_mlp(&mut rng, &[3, 5, 2], LossKind::Mse);
        g.layers[0].activation = Activation::Tanh;
        let x = Matrix::from_fn(6, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(6, 2, |_, _| rng.normal());
        let w0 = g.layers[0].w.clone();
        let loss_at = |gr: &Graph| gr.loss.loss(&gr.forward(&x), &y);
        let eps = 1e-3f32;
        let mut num_grad = vec![0.0f32; 4];
        let probes = [(0usize, 0usize), (1, 2), (2, 4), (0, 3)];
        for (pi, &(r, c)) in probes.iter().enumerate() {
            let mut gp = g.clone();
            gp.layers[0].w[(r, c)] += eps;
            gp.layers[0].invalidate_w_t();
            let mut gm = g.clone();
            gm.layers[0].w[(r, c)] -= eps;
            gm.layers[0].invalidate_w_t();
            num_grad[pi] = (loss_at(&gp) - loss_at(&gm)) / (2.0 * eps);
        }
        let eta = 0.05f32;
        train_step_exact(&mut g, &x, &y, eta, &Executor::serial());
        for (pi, &(r, c)) in probes.iter().enumerate() {
            let applied = (w0[(r, c)] - g.layers[0].w[(r, c)]) / eta;
            assert!(
                (applied - num_grad[pi]).abs() < 2e-2,
                "({r},{c}): applied {applied} vs numeric {}",
                num_grad[pi]
            );
        }
    }

    #[test]
    fn non_identity_head_matches_numeric_gradient() {
        // a sigmoid *head* must pick up the act'(h) chain factor on the
        // loss gradient — at every layer, not just below the head
        let mut rng = Rng::new(10);
        let mut g = Graph::relu_mlp(&mut rng, &[3, 5, 2], LossKind::Mse);
        g.layers[0].activation = Activation::Tanh; // smooth everywhere
        g.layers[1].activation = Activation::Sigmoid;
        let x = Matrix::from_fn(6, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(6, 2, |_, _| rng.uniform());
        let loss_at = |gr: &Graph| gr.loss.loss(&gr.forward(&x), &y);
        let eps = 1e-3f32;
        // probe both the head's and the hidden layer's weights
        let probes = [(1usize, 0usize, 0usize), (1, 4, 1), (0, 0, 2), (0, 2, 3)];
        let mut num_grad = vec![0.0f32; probes.len()];
        for (pi, &(li, r, c)) in probes.iter().enumerate() {
            let mut gp = g.clone();
            gp.layers[li].w[(r, c)] += eps;
            gp.layers[li].invalidate_w_t();
            let mut gm = g.clone();
            gm.layers[li].w[(r, c)] -= eps;
            gm.layers[li].invalidate_w_t();
            num_grad[pi] = (loss_at(&gp) - loss_at(&gm)) / (2.0 * eps);
        }
        let w0: Vec<Matrix> = g.layers.iter().map(|l| l.w.clone()).collect();
        let eta = 0.05f32;
        train_step_exact(&mut g, &x, &y, eta, &Executor::serial());
        for (pi, &(li, r, c)) in probes.iter().enumerate() {
            let applied = (w0[li][(r, c)] - g.layers[li].w[(r, c)]) / eta;
            assert!(
                (applied - num_grad[pi]).abs() < 2e-2,
                "layer {li} ({r},{c}): applied {applied} vs numeric {}",
                num_grad[pi]
            );
        }
    }

    #[test]
    fn memory_defers_unselected_rows_per_layer() {
        let mut rng = Rng::new(9);
        let mut g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::Mse);
        let x = Matrix::from_fn(16, 4, |_, _| rng.normal());
        let y = Matrix::from_fn(16, 2, |_, _| rng.normal());
        let mut state = GraphState::uniform(&g, 16, Policy::TopK, 4, true);
        train_step(&mut g, &mut state, &x, &y, 0.05, &mut rng, &Executor::serial(), true);
        for ls in &state.layers {
            let nz = (0..16)
                .filter(|&r| ls.mem.mem_x.row(r).iter().any(|&v| v != 0.0))
                .count();
            assert_eq!(nz, 12, "12 unselected rows must sit in memory");
        }
        assert!(state.deferred_mass() > 0.0);
    }

    #[test]
    #[should_panic(expected = "apply called without fwd_score")]
    fn apply_without_fwd_score_panics() {
        let mut rng = Rng::new(11);
        let mut g = Graph::relu_mlp(&mut rng, &[4, 2], LossKind::Mse);
        let mut state = GraphState::exact(&g, 8);
        let mut ws = GraphWorkspace::new(&g, 8);
        let sels = vec![policy::select_exact(8)];
        apply(&mut g, &mut state, &sels, 0.1, &Executor::serial(), true, &mut ws);
    }
}
