//! [`GraphWorkspace`] — the reusable per-run arena behind the
//! zero-allocation training step (§Perf pass).
//!
//! One Mem-AOP-GD step needs a surprising amount of transient storage:
//! the forward trace, the backward gradient chain, per-layer `X̂`/`Ĝ`
//! foldings, policy scores, bias-gradient and outer-product shard
//! partials, and the per-layer selections. Before this type existed a
//! single step performed dozens of `Matrix::zeros`/`transpose`/`Vec`
//! heap allocations; now every buffer lives here, keyed by
//! **graph shape × batch size**, and is reused step after step — a
//! steady-state step allocates nothing (asserted by the allocation
//! counter in `benches/kernels.rs`). Selection buffers and the policy
//! scratch are sized for the batch up front, which bounds every possible
//! budget: resolved K schedules clamp to `[1, batch]`, so a mid-run k
//! change (per-layer K annealing) is also allocation-free.
//!
//! Ownership rules:
//!
//! * every long-lived training surface owns one workspace —
//!   `NativeTrainer` (and through it every serve job) and `AopEngine`
//!   construct theirs up front; the convenience wrappers
//!   (`train::train_step`, the MLP methods) build a throwaway workspace
//!   per call, trading allocations for API simplicity on cold paths;
//! * [`GraphWorkspace::ensure`] re-keys (reallocates) only when the
//!   graph widths or the batch size actually change, so calling it at
//!   the top of every step is free in steady state;
//! * buffers hold *stale* values between steps by design — every kernel
//!   that reads a workspace buffer either overwrote it first or zeroes
//!   it (`*_into` kernels `fill(0.0)` before accumulating). The one
//!   deliberate exception: `scores[i]` of an `Exact`-policy layer is
//!   never written (exact selection reads no scores) and must be treated
//!   as undefined.

use std::sync::Mutex;

use crate::aop::policy::{SelectScratch, Selection};
use crate::exec::plan::ShardPlan;
use crate::obs::{ObsConfig, StepTelemetry};
use crate::tensor::quant::{LayerPrecision, TraceBuf, TraceMode};
use crate::tensor::{ops, Matrix};
use crate::train::graph::Graph;

/// Reusable step storage for one (graph shape, batch size) key. See the
/// module docs for the ownership and staleness rules.
pub struct GraphWorkspace {
    /// Key: the graph's width chain `[fan_in_0, fan_out_0, ..]`.
    pub(crate) widths: Vec<usize>,
    /// Key: rows per training batch.
    pub(crate) batch: usize,
    /// Shards of the canonical plan for `batch` rows.
    pub(crate) n_shards: usize,

    /// Forward trace: `acts[i]` is layer i's activated output
    /// (batch × fan_out_i), stored at the layer's resolved trace
    /// precision (§Mixed precision) — `F32` buffers are written directly
    /// by the forward; quantized buffers are encoded per shard from
    /// their exact staging matrix and dequantized on read by the
    /// backward kernels.
    pub(crate) acts: Vec<TraceBuf>,
    /// Per-layer resolved precision (trace mode + accumulation mode).
    /// Not part of the (widths, batch) key — changed via
    /// [`Self::set_precision`], preserved across [`Self::ensure`]
    /// re-keys like the obs config.
    pub(crate) prec: Vec<LayerPrecision>,
    /// Backward chain: `grads[i]` is ∂L/∂acts\[i\] (batch × fan_out_i).
    pub(crate) grads: Vec<Matrix>,
    /// Folded `X̂` per layer (batch × fan_in_i).
    pub(crate) xhat: Vec<Matrix>,
    /// Folded `Ĝ` per layer (batch × fan_out_i).
    pub(crate) ghat: Vec<Matrix>,
    /// Policy scores per layer (len batch; undefined for Exact layers).
    pub(crate) scores: Vec<Vec<f32>>,
    /// Reduced bias gradient per layer (len fan_out_i).
    pub(crate) db: Vec<Vec<f32>>,

    /// Per-shard (loss partial, correct count) slots for the head pass.
    pub(crate) loss_parts: Vec<Mutex<(f32, usize)>>,
    /// Per-shard bias-gradient partials: row `si` holds shard si's
    /// column sums in its first fan_out_i entries (cols = max fan_out).
    pub(crate) db_parts: Matrix,
    /// Per-layer outer-product shard partials in the layer's
    /// [`ops::aop_layout`]: `(n_shards · a_i) × b_i`, block si = rows
    /// `[si·a_i, (si+1)·a_i)`.
    pub(crate) wstar_parts: Vec<Matrix>,
    /// Per-layer reduced `Ŵ*` in the same layout (`a_i × b_i`).
    pub(crate) wstar: Vec<Matrix>,

    /// Per-layer reusable selections (moved out during `apply`, moved
    /// back after — `std::mem::take` swaps with an unallocated Vec).
    pub(crate) sels: Vec<Selection>,
    /// Policy scratch shared by every layer's draw.
    pub(crate) scratch: SelectScratch,
    /// Per-layer distinct outer products of the last applied step.
    pub(crate) layer_k: Vec<usize>,
    /// Set by `fwd_score` (loss, acc), consumed by `apply` — the pairing
    /// guard behind the "apply called without fwd_score" panic.
    pub(crate) fwd: Option<(f32, f32)>,

    /// Step telemetry (ISSUE 6): per-phase timing histograms, per-layer
    /// realized-K/FLOP counters and the bounded event trace — pre-sized
    /// here so recording on the hot path allocates nothing. Off by
    /// default for raw workspaces; `NativeTrainer` turns it on.
    pub(crate) obs: StepTelemetry,

    /// Audit scratch (ISSUE 7), sized lazily by [`Self::ensure_audit`]
    /// so audit-off runs pay nothing: per-layer copies of the applied
    /// update and the exact folded gradient (both in the layer's
    /// [`ops::aop_layout`]), plus one reusable K=M selection.
    pub(crate) audit_approx: Vec<Matrix>,
    pub(crate) audit_exact: Vec<Matrix>,
    pub(crate) audit_sel: Selection,
}

impl GraphWorkspace {
    /// Allocate every buffer for `graph` at `batch` rows, telemetry off
    /// (no timer reads on the step path).
    pub fn new(graph: &Graph, batch: usize) -> GraphWorkspace {
        GraphWorkspace::with_obs(graph, batch, ObsConfig::off())
    }

    /// [`GraphWorkspace::new`] with an explicit [`ObsConfig`] — the
    /// telemetry's histograms, counters and trace ring are sized here,
    /// up front, so enabled telemetry stays zero-allocation per step.
    /// All-f32 precision (the seed behavior).
    pub fn with_obs(graph: &Graph, batch: usize, obs: ObsConfig) -> GraphWorkspace {
        let prec = vec![LayerPrecision::exact(); graph.layers.len()];
        GraphWorkspace::with_precision(graph, batch, obs, &prec)
    }

    /// Fully-keyed constructor: per-layer precision decides each trace
    /// buffer's storage (and pre-sizes the quantized variants' code +
    /// staging buffers, keeping steady-state steps allocation-free).
    ///
    /// Pinned choice: the **last (head) layer's trace is always stored
    /// f32** — its activations feed only the loss head (exact by
    /// design), never a backward trace read, so quantizing it would
    /// cost encode time and buy nothing. A quantized mode requested for
    /// the head is silently resolved to `F32` here (the config layer
    /// applies the same pin at `layer_plan()` resolution, so a resolved
    /// plan round-trips unchanged).
    pub fn with_precision(
        graph: &Graph,
        batch: usize,
        obs: ObsConfig,
        prec: &[LayerPrecision],
    ) -> GraphWorkspace {
        assert!(batch > 0, "workspace needs a non-empty batch");
        assert_eq!(prec.len(), graph.layers.len(), "one LayerPrecision per layer");
        let mut prec = prec.to_vec();
        if let Some(last) = prec.last_mut() {
            last.trace = TraceMode::F32;
        }
        let widths = graph.widths();
        let n = graph.layers.len();
        let n_shards = ShardPlan::for_rows(batch).len();
        let max_pf = graph.layers.iter().map(|l| l.fan_out()).max().unwrap();
        let mut wstar_parts = Vec::with_capacity(n);
        let mut wstar = Vec::with_capacity(n);
        for l in &graph.layers {
            let (a, b) = ops::aop_layout(l.fan_in(), l.fan_out());
            wstar_parts.push(Matrix::zeros(n_shards * a, b));
            wstar.push(Matrix::zeros(a, b));
        }
        GraphWorkspace {
            batch,
            n_shards,
            acts: graph
                .layers
                .iter()
                .zip(prec.iter())
                .map(|(l, p)| TraceBuf::new(p.trace, batch, l.fan_out()))
                .collect(),
            grads: graph
                .layers
                .iter()
                .map(|l| Matrix::zeros(batch, l.fan_out()))
                .collect(),
            xhat: graph
                .layers
                .iter()
                .map(|l| Matrix::zeros(batch, l.fan_in()))
                .collect(),
            ghat: graph
                .layers
                .iter()
                .map(|l| Matrix::zeros(batch, l.fan_out()))
                .collect(),
            scores: (0..n).map(|_| vec![0.0f32; batch]).collect(),
            db: graph
                .layers
                .iter()
                .map(|l| vec![0.0f32; l.fan_out()])
                .collect(),
            loss_parts: (0..n_shards).map(|_| Mutex::new((0.0, 0))).collect(),
            db_parts: Matrix::zeros(n_shards, max_pf),
            wstar_parts,
            wstar,
            sels: (0..n).map(|_| Selection::with_capacity(batch)).collect(),
            // pre-sized for the batch: every selection buffer covers any
            // k ≤ batch (resolved K schedules clamp to [1, batch]), so
            // mid-run budget changes stay zero-allocation
            scratch: SelectScratch::with_capacity(batch),
            layer_k: Vec::with_capacity(n),
            fwd: None,
            obs: StepTelemetry::new(obs, n),
            audit_approx: Vec::new(),
            audit_exact: Vec::new(),
            audit_sel: Selection::with_capacity(0),
            prec,
            widths,
        }
    }

    /// Size the audit scratch for this workspace's key. Cheap when
    /// already sized (a length check), so the auditor calls it every
    /// time; a re-key drops the scratch and the next audit rebuilds it.
    pub(crate) fn ensure_audit(&mut self) {
        let n = self.widths.len() - 1;
        if self.audit_approx.len() == n {
            return;
        }
        self.audit_approx = self
            .wstar
            .iter()
            .map(|w| {
                let (a, b) = w.shape();
                Matrix::zeros(a, b)
            })
            .collect();
        self.audit_exact = self
            .wstar
            .iter()
            .map(|w| {
                let (a, b) = w.shape();
                Matrix::zeros(a, b)
            })
            .collect();
        self.audit_sel = Selection::with_capacity(self.batch);
    }

    /// Whether this workspace is keyed for (`graph`, `batch`).
    /// Allocation-free (called at the top of every step): compares the
    /// width chain element-wise instead of materializing
    /// `graph.widths()`.
    pub fn matches(&self, graph: &Graph, batch: usize) -> bool {
        self.batch == batch
            && self.widths.len() == graph.layers.len() + 1
            && self.widths[0] == graph.layers[0].fan_in()
            && graph
                .layers
                .iter()
                .zip(self.widths[1..].iter())
                .all(|(l, &w)| l.fan_out() == w)
    }

    /// Re-key (reallocate everything) iff the key changed — a cheap
    /// width-chain comparison in steady state. The obs *configuration*
    /// survives a re-key (the telemetry buffers are rebuilt for the new
    /// layer count, resetting recorded data like every other buffer),
    /// and so does the per-layer precision — as long as the layer count
    /// is unchanged (a different layer count has no meaningful mapping
    /// from the old precision vector, so it resets to all-f32).
    pub fn ensure(&mut self, graph: &Graph, batch: usize) {
        if !self.matches(graph, batch) {
            let prec = if self.prec.len() == graph.layers.len() {
                std::mem::take(&mut self.prec)
            } else {
                vec![LayerPrecision::exact(); graph.layers.len()]
            };
            *self = GraphWorkspace::with_precision(graph, batch, self.obs.config(), &prec);
        }
    }

    /// Reconfigure per-layer precision in place. A config-time
    /// operation: rebuilds the workspace when the precision actually
    /// changes (trace buffers are storage-typed), no-op otherwise —
    /// never call mid-step.
    pub fn set_precision(&mut self, graph: &Graph, prec: &[LayerPrecision]) {
        assert_eq!(prec.len(), self.widths.len() - 1, "one LayerPrecision per layer");
        // apply the head pin before comparing, so passing an unpinned
        // vector repeatedly never re-keys twice (config-time alloc only)
        let mut want = prec.to_vec();
        if let Some(last) = want.last_mut() {
            last.trace = TraceMode::F32;
        }
        if self.prec != want {
            *self = GraphWorkspace::with_precision(graph, self.batch, self.obs.config(), &want);
        }
    }

    /// The per-layer resolved precision this workspace was built with
    /// (head trace pinned to `F32` — see [`Self::with_precision`]).
    pub fn precision(&self) -> &[LayerPrecision] {
        &self.prec
    }

    /// Bytes the backward pass reads from layer `li`'s activation trace.
    pub fn layer_trace_bytes(&self, li: usize) -> usize {
        self.acts[li].trace_bytes()
    }

    /// Total backward-read trace footprint across all layers — the
    /// number BENCH_9 and the `repro_trace_bytes` gauge report.
    pub fn trace_bytes(&self) -> usize {
        self.acts.iter().map(|t| t.trace_bytes()).sum()
    }

    /// The batch size this workspace is keyed for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Layer `li`'s policy scores from the last `fwd_score` (undefined
    /// for Exact-policy layers — see the module docs).
    pub fn scores(&self, li: usize) -> &[f32] {
        &self.scores[li]
    }

    /// Layer `li`'s reduced raw bias gradient from the last `fwd_score`.
    pub fn db(&self, li: usize) -> &[f32] {
        &self.db[li]
    }

    /// Layer `li`'s folded `X̂` from the last `fwd_score`.
    pub fn xhat(&self, li: usize) -> &Matrix {
        &self.xhat[li]
    }

    /// Layer `li`'s folded `Ĝ` from the last `fwd_score`.
    pub fn ghat(&self, li: usize) -> &Matrix {
        &self.ghat[li]
    }

    /// Per-layer distinct outer products applied by the last `apply`.
    pub fn layer_k(&self) -> &[usize] {
        &self.layer_k
    }

    /// The per-layer selections drawn by the last `select_layers_ws`.
    pub fn selections(&self) -> &[Selection] {
        &self.sels
    }

    /// The step telemetry handle (histograms, counters, trace).
    pub fn obs(&self) -> &StepTelemetry {
        &self.obs
    }

    /// Mutable telemetry handle (external phase recording).
    pub fn obs_mut(&mut self) -> &mut StepTelemetry {
        &mut self.obs
    }

    /// Reconfigure telemetry in place. A config-time operation: rebuilds
    /// the telemetry buffers (allocates) and resets recorded data —
    /// never call mid-step.
    pub fn set_obs(&mut self, cfg: ObsConfig) {
        let n = self.widths.len() - 1;
        self.obs = StepTelemetry::new(cfg, n);
    }

    /// Move the selection vector out (so `apply` can borrow the
    /// workspace mutably alongside it); pair with [`Self::put_sels`].
    /// `std::mem::take` leaves an unallocated Vec — no heap traffic.
    pub(crate) fn take_sels(&mut self) -> Vec<Selection> {
        std::mem::take(&mut self.sels)
    }

    pub(crate) fn put_sels(&mut self, sels: Vec<Selection>) {
        self.sels = sels;
    }

    /// Drop a pending `fwd_score` result without applying it (the
    /// optimizer path computes its own update from the fwd buffers).
    pub(crate) fn clear_fwd(&mut self) {
        self.fwd = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::loss::LossKind;
    use crate::tensor::rng::Rng;

    #[test]
    fn keyed_by_widths_and_batch() {
        let mut rng = Rng::new(0);
        let g = Graph::relu_mlp(&mut rng, &[6, 10, 3], LossKind::Mse);
        let mut ws = GraphWorkspace::new(&g, 32);
        assert!(ws.matches(&g, 32));
        assert!(!ws.matches(&g, 16));
        assert_eq!(ws.n_shards, 2); // 32 rows on the 16-row grid
        assert_eq!(ws.acts.len(), 2);
        assert_eq!(ws.xhat[0].shape(), (32, 6));
        assert_eq!(ws.ghat[1].shape(), (32, 3));
        // ensure() re-keys on batch change, keeps on match
        ws.ensure(&g, 32);
        assert_eq!(ws.batch(), 32);
        ws.ensure(&g, 48);
        assert!(ws.matches(&g, 48));
        assert_eq!(ws.n_shards, 3);
        // a different graph shape re-keys too
        let g2 = Graph::relu_mlp(&mut rng, &[6, 11, 3], LossKind::Mse);
        ws.ensure(&g2, 48);
        assert!(ws.matches(&g2, 48));
        assert!(!ws.matches(&g, 48));
    }

    #[test]
    fn partial_buffers_follow_aop_layout() {
        let mut rng = Rng::new(1);
        // 784 → 10 takes the transposed layout; 10 → 784 does not
        let g = Graph::relu_mlp(&mut rng, &[784, 10], LossKind::Mse);
        let ws = GraphWorkspace::new(&g, 64);
        assert!(ops::aop_transposed(784, 10));
        assert_eq!(ws.wstar[0].shape(), (10, 784));
        assert_eq!(ws.wstar_parts[0].shape(), (4 * 10, 784));
    }

    #[test]
    fn obs_config_survives_ensure_rekey() {
        let mut rng = Rng::new(3);
        let g = Graph::relu_mlp(&mut rng, &[6, 10, 3], LossKind::Mse);
        let mut ws = GraphWorkspace::with_obs(&g, 32, ObsConfig::with_trace_capacity(16));
        assert!(ws.obs().enabled());
        ws.ensure(&g, 48); // re-key: buffers rebuilt, config preserved
        assert!(ws.obs().enabled(), "obs config must survive a re-key");
        assert_eq!(ws.obs().config().trace_capacity, 16);
        ws.set_obs(ObsConfig::off());
        assert!(!ws.obs().enabled());
        // plain construction defaults to off (no timer reads)
        assert!(!GraphWorkspace::new(&g, 16).obs().enabled());
    }

    #[test]
    fn audit_scratch_is_lazy_and_dropped_on_rekey() {
        let mut rng = Rng::new(5);
        let g = Graph::relu_mlp(&mut rng, &[6, 10, 3], LossKind::Mse);
        let mut ws = GraphWorkspace::new(&g, 32);
        assert!(ws.audit_approx.is_empty(), "audit-off runs pay nothing");
        ws.ensure_audit();
        assert_eq!(ws.audit_approx.len(), 2);
        assert_eq!(ws.audit_approx[0].shape(), ws.wstar[0].shape());
        assert_eq!(ws.audit_exact[1].shape(), ws.wstar[1].shape());
        ws.ensure_audit(); // idempotent
        assert_eq!(ws.audit_approx.len(), 2);
        ws.ensure(&g, 48);
        assert!(ws.audit_approx.is_empty(), "re-key drops the scratch");
    }

    #[test]
    fn precision_shapes_trace_buffers_and_survives_rekey() {
        let mut rng = Rng::new(7);
        let g = Graph::relu_mlp(&mut rng, &[6, 10, 3], LossKind::Mse);
        let mut ws = GraphWorkspace::new(&g, 32);
        // default: all f32, seed footprint
        assert_eq!(ws.trace_bytes(), 4 * 32 * 10 + 4 * 32 * 3);
        let prec = [
            LayerPrecision { trace: TraceMode::Bf16, accum: crate::tensor::quant::AccumMode::F64 },
            // head: quantized request is pinned back to f32
            LayerPrecision { trace: TraceMode::Q8, accum: crate::tensor::quant::AccumMode::F64 },
        ];
        ws.set_precision(&g, &prec);
        assert_eq!(ws.precision()[0].trace, TraceMode::Bf16);
        assert_eq!(ws.precision()[1].trace, TraceMode::F32, "head trace pinned to f32");
        assert_eq!(ws.layer_trace_bytes(0), 2 * 32 * 10);
        assert_eq!(ws.layer_trace_bytes(1), 4 * 32 * 3);
        // idempotent: same precision does not re-key (acts keep identity)
        let before = ws.acts[0].exact().data().as_ptr();
        ws.set_precision(&g, &prec);
        assert_eq!(ws.acts[0].exact().data().as_ptr(), before);
        // precision survives a batch re-key, like the obs config
        ws.ensure(&g, 48);
        assert_eq!(ws.precision()[0].trace, TraceMode::Bf16);
        assert_eq!(ws.layer_trace_bytes(0), 2 * 48 * 10);
        // a layer-count change resets precision to all-f32
        let g2 = Graph::relu_mlp(&mut rng, &[6, 8, 8, 3], LossKind::Mse);
        ws.ensure(&g2, 48);
        assert!(ws.precision().iter().all(|p| *p == LayerPrecision::exact()));
    }

    #[test]
    #[should_panic(expected = "non-empty batch")]
    fn zero_batch_rejected() {
        let mut rng = Rng::new(2);
        let g = Graph::relu_mlp(&mut rng, &[4, 2], LossKind::Mse);
        GraphWorkspace::new(&g, 0);
    }
}
