//! `train` — the single Mem-AOP-GD training core.
//!
//! Everything that *trains* in this crate goes through this module:
//!
//! * [`layer`] — [`Dense`] (`h = act(x W + b)`) with a pluggable
//!   [`Activation`](crate::model::activations::Activation), plus the
//!   per-layer [`AopLayerConfig`] `{k, policy, memory}` — Algorithm 1's
//!   design knobs, resolvable layer-by-layer;
//! * [`graph`] — [`Graph`] (an ordered layer chain + loss head) and
//!   [`GraphState`] (per-layer config + error-feedback memory);
//! * [`step`] — the one implementation of the Mem-AOP-GD step on the
//!   `exec` row-shard primitives, phase-split (`fwd_score` / caller-owned
//!   per-layer `out_K` / `apply`) exactly like the compiled HLO
//!   artifacts;
//! * [`workspace`] — [`GraphWorkspace`], the reusable per-run arena
//!   (trace, gradients, foldings, scores, shard partials, selections)
//!   keyed by graph shape × batch size; with a resident workspace a
//!   steady-state step performs **zero heap allocations** (§Perf pass,
//!   asserted by `benches/kernels.rs`).
//!
//! The adapters are deliberately thin: `aop::AopEngine` is a 1-layer
//! identity-activation graph, `model::mlp::Mlp` *is* [`Graph`], and the
//! coordinator's `NativeTrainer` (hence the serve job path) drives the
//! phase-split functions directly — each owning one workspace. There is
//! no second copy of the forward/fold/score/masked-outer math anywhere.

pub mod graph;
pub mod layer;
pub mod step;
pub mod workspace;

pub use graph::{Graph, GraphState, LayerState};
pub use layer::{AopLayerConfig, Dense};
pub use step::{
    aop_weight_grad_ws, apply, audit_into, fwd_score, select_layers_ws, select_with_configs,
    train_step, train_step_exact, train_step_exact_ws, train_step_ws, StepOutcome,
};
pub use workspace::GraphWorkspace;
