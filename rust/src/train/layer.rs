//! The layer abstraction of the training core: a dense affine map with a
//! pluggable elementwise [`Activation`], plus the per-layer Mem-AOP-GD
//! knobs ([`AopLayerConfig`]) the paper's Algorithm 1 parameterizes each
//! layer with.

use crate::aop::Policy;
use crate::model::activations::Activation;
use crate::tensor::{init, rng::Rng, Matrix};

/// One dense layer `h = act(x W + b)`.
#[derive(Debug, Clone)]
pub struct Dense {
    pub w: Matrix,
    pub b: Vec<f32>,
    pub activation: Activation,
}

impl Dense {
    /// Glorot-uniform weights, zero bias (Keras default).
    pub fn glorot(rng: &mut Rng, fan_in: usize, fan_out: usize, activation: Activation) -> Self {
        Dense {
            w: init::glorot_uniform(rng, fan_in, fan_out),
            b: init::zeros_bias(fan_out),
            activation,
        }
    }

    /// Wrap existing weights (zero bias) — the single-layer engine path.
    pub fn from_weights(w: Matrix, activation: Activation) -> Self {
        let p = w.cols();
        Dense {
            w,
            b: vec![0.0; p],
            activation,
        }
    }

    /// Pre-activation output `z = x W + b` (serial whole-batch path; the
    /// training step uses the row-sharded `exec::shard::forward_rows`).
    pub fn forward_z(&self, x: &Matrix) -> Matrix {
        x.matmul(&self.w).add_row_broadcast(&self.b)
    }

    /// Activated output `act(x W + b)`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.activation.apply_owned(self.forward_z(x))
    }

    pub fn fan_in(&self) -> usize {
        self.w.rows()
    }

    pub fn fan_out(&self) -> usize {
        self.w.cols()
    }

    pub fn num_params(&self) -> usize {
        self.w.rows() * self.w.cols() + self.b.len()
    }
}

/// Per-layer Mem-AOP-GD configuration: the approximation budget K, the
/// `out_K` selection policy, and the error-feedback memory toggle —
/// Algorithm 1's design knobs, resolvable layer-by-layer (heterogeneous
/// budgets are where the interesting regimes live).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AopLayerConfig {
    /// Outer products kept per update at this layer (K ≤ M).
    pub k: usize,
    /// The `out_K` operator for this layer.
    pub policy: Policy,
    /// Error-feedback memory on/off for this layer.
    pub memory: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn dense_shapes_and_params() {
        let mut rng = Rng::new(0);
        let d = Dense::glorot(&mut rng, 8, 3, Activation::Relu);
        assert_eq!(d.fan_in(), 8);
        assert_eq!(d.fan_out(), 3);
        assert_eq!(d.num_params(), 8 * 3 + 3);
        let x = Matrix::from_fn(5, 8, |_, _| rng.normal());
        let h = d.forward(&x);
        assert_eq!(h.shape(), (5, 3));
        // relu output is non-negative
        assert!(h.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn forward_matches_manual() {
        let mut rng = Rng::new(1);
        let d = Dense::from_weights(Matrix::from_fn(4, 2, |_, _| rng.normal()), Activation::Identity);
        let x = Matrix::from_fn(3, 4, |_, _| rng.normal());
        let manual = x.matmul(&d.w).add_row_broadcast(&d.b);
        assert_eq!(d.forward(&x).data(), manual.data());
    }
}
