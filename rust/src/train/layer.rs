//! The layer abstraction of the training core: a dense affine map with a
//! pluggable elementwise [`Activation`], plus the per-layer Mem-AOP-GD
//! knobs ([`AopLayerConfig`]) the paper's Algorithm 1 parameterizes each
//! layer with.

use std::sync::OnceLock;

use crate::aop::Policy;
use crate::model::activations::Activation;
use crate::tensor::{init, ops, rng::Rng, Matrix};

/// One dense layer `h = act(x W + b)`.
///
/// `w_t` is a lazily-maintained transpose cache (§Perf pass): the
/// training step's narrow-B forward path and the backward chain
/// `G W^T` both want `W^T`, and before the cache every shard of every
/// step re-transposed the weights. [`Dense::w_t`] computes it on first
/// use (thread-safe — shard closures may race on the first touch, one
/// wins) and [`Dense::refresh_w_t`] rewrites it **in place** after the
/// weight update in `train::apply`, so steady-state steps never
/// transpose per shard and never allocate for it.
///
/// Invariant: any code that mutates `w` directly (outside
/// `train::apply` / the optimizer step, which refresh it) must call
/// [`Dense::invalidate_w_t`] — a stale cache silently corrupts the
/// backward pass. The cache is populated by *any* consumer of
/// [`Dense::w_t`] (a training step's forward/backward, `evaluate_exec`,
/// a direct call), so "freshly built" is the only state where a direct
/// `w[(r, c)]` poke is safe without invalidating; when in doubt, call
/// `invalidate_w_t` — it costs one lazy re-transpose at most.
#[derive(Debug, Clone)]
pub struct Dense {
    pub w: Matrix,
    pub b: Vec<f32>,
    pub activation: Activation,
    w_t: OnceLock<Matrix>,
}

impl Dense {
    /// Glorot-uniform weights, zero bias (Keras default).
    pub fn glorot(rng: &mut Rng, fan_in: usize, fan_out: usize, activation: Activation) -> Self {
        Dense {
            w: init::glorot_uniform(rng, fan_in, fan_out),
            b: init::zeros_bias(fan_out),
            activation,
            w_t: OnceLock::new(),
        }
    }

    /// Wrap existing weights (zero bias) — the single-layer engine path.
    pub fn from_weights(w: Matrix, activation: Activation) -> Self {
        let p = w.cols();
        Dense {
            w,
            b: vec![0.0; p],
            activation,
            w_t: OnceLock::new(),
        }
    }

    /// `W^T`, computed once and cached (see the type docs for the
    /// maintenance contract).
    pub fn w_t(&self) -> &Matrix {
        self.w_t.get_or_init(|| self.w.transpose())
    }

    /// The cached transpose, warmed only when this layer's *forward*
    /// narrow-B kernel will actually read it — wide layers return `None`
    /// and their cache stays cold, costing nothing here or in the
    /// per-step refresh. The one definition of the warm predicate for
    /// every forward path (training step and evaluation).
    pub fn warmed_w_t(&self) -> Option<&Matrix> {
        if ops::matmul_uses_bt(self.fan_in(), self.fan_out()) {
            Some(self.w_t())
        } else {
            None
        }
    }

    /// Re-derive the cache from the current `w`, reusing its buffer —
    /// zero allocations once populated. No-op while the cache is cold
    /// (the next [`Dense::w_t`] call recomputes lazily anyway).
    pub fn refresh_w_t(&mut self) {
        if let Some(mut t) = self.w_t.take() {
            self.w.transpose_into(&mut t);
            let _ = self.w_t.set(t);
        }
    }

    /// Drop the cache after an out-of-band mutation of `w`.
    pub fn invalidate_w_t(&mut self) {
        self.w_t.take();
    }

    /// Pre-activation output `z = x W + b` (serial whole-batch path; the
    /// training step uses the row-sharded `exec::shard::forward_rows`).
    pub fn forward_z(&self, x: &Matrix) -> Matrix {
        x.matmul(&self.w).add_row_broadcast(&self.b)
    }

    /// Activated output `act(x W + b)`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.activation.apply_owned(self.forward_z(x))
    }

    pub fn fan_in(&self) -> usize {
        self.w.rows()
    }

    pub fn fan_out(&self) -> usize {
        self.w.cols()
    }

    pub fn num_params(&self) -> usize {
        self.w.rows() * self.w.cols() + self.b.len()
    }
}

/// Per-layer Mem-AOP-GD configuration: the approximation budget K, the
/// `out_K` selection policy, and the error-feedback memory toggle —
/// Algorithm 1's design knobs, resolvable layer-by-layer (heterogeneous
/// budgets are where the interesting regimes live).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AopLayerConfig {
    /// Outer products kept per update at this layer (K ≤ M).
    pub k: usize,
    /// The `out_K` operator for this layer.
    pub policy: Policy,
    /// Error-feedback memory on/off for this layer.
    pub memory: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn dense_shapes_and_params() {
        let mut rng = Rng::new(0);
        let d = Dense::glorot(&mut rng, 8, 3, Activation::Relu);
        assert_eq!(d.fan_in(), 8);
        assert_eq!(d.fan_out(), 3);
        assert_eq!(d.num_params(), 8 * 3 + 3);
        let x = Matrix::from_fn(5, 8, |_, _| rng.normal());
        let h = d.forward(&x);
        assert_eq!(h.shape(), (5, 3));
        // relu output is non-negative
        assert!(h.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn w_t_cache_tracks_weight_updates() {
        let mut rng = Rng::new(2);
        let mut d = Dense::glorot(&mut rng, 6, 4, Activation::Identity);
        assert_eq!(d.w_t().data(), d.w.transpose().data());
        // refresh after an in-place update keeps the cache exact
        d.w.axpy(0.5, &Matrix::full(6, 4, 1.0));
        d.refresh_w_t();
        assert_eq!(d.w_t().data(), d.w.transpose().data());
        // invalidation recomputes lazily
        d.w[(0, 0)] += 1.0;
        d.invalidate_w_t();
        assert_eq!(d.w_t().data(), d.w.transpose().data());
    }

    #[test]
    fn refresh_on_cold_cache_is_noop_then_lazy() {
        let mut rng = Rng::new(3);
        let mut d = Dense::glorot(&mut rng, 3, 2, Activation::Relu);
        d.refresh_w_t(); // cold: nothing to rewrite
        d.w[(1, 1)] = 42.0;
        assert_eq!(d.w_t()[(1, 1)], 42.0);
    }

    #[test]
    fn forward_matches_manual() {
        let mut rng = Rng::new(1);
        let d = Dense::from_weights(Matrix::from_fn(4, 2, |_, _| rng.normal()), Activation::Identity);
        let x = Matrix::from_fn(3, 4, |_, _| rng.normal());
        let manual = x.matmul(&d.w).add_row_broadcast(&d.b);
        assert_eq!(d.forward(&x).data(), manual.data());
    }
}
