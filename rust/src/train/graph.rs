//! The layer graph: an ordered chain of [`Dense`] layers with one loss
//! head, plus the per-layer training state ([`GraphState`]) that pairs
//! each layer with its [`AopLayerConfig`] and error-feedback memory.
//!
//! This is the one model type every training surface shares: the paper's
//! single dense layer is a 1-layer identity-activation graph
//! (`AopEngine`), the MLP is a relu-hidden graph (`model::mlp`), and the
//! coordinator builds graphs straight from `ExperimentConfig`.

use crate::aop::{MemoryState, Policy};
use crate::exec::{shard, Executor};
use crate::model::activations::Activation;
use crate::model::loss::{self, LossKind};
use crate::tensor::{rng::Rng, Matrix};

use crate::train::layer::{AopLayerConfig, Dense};
use crate::train::workspace::GraphWorkspace;

/// A feed-forward chain of dense layers trained with Mem-AOP-GD.
#[derive(Debug, Clone)]
pub struct Graph {
    pub layers: Vec<Dense>,
    pub loss: LossKind,
}

impl Graph {
    /// Build from explicit layers; dims must chain.
    pub fn new(layers: Vec<Dense>, loss: LossKind) -> Graph {
        assert!(!layers.is_empty(), "a graph needs at least one layer");
        for win in layers.windows(2) {
            assert_eq!(
                win[0].fan_out(),
                win[1].fan_in(),
                "layer dims must chain: {} -> {}",
                win[0].fan_out(),
                win[1].fan_in()
            );
        }
        Graph { layers, loss }
    }

    /// The paper's single dense layer: one identity-activation `Dense`
    /// wrapping `w` with zero bias.
    pub fn single(w: Matrix, loss: LossKind) -> Graph {
        Graph::new(vec![Dense::from_weights(w, Activation::Identity)], loss)
    }

    /// Classic MLP over `widths` (e.g. `[784, 1024, 1024, 10]`): glorot
    /// init, relu hidden layers, identity head.
    pub fn relu_mlp(rng: &mut Rng, widths: &[usize], loss: LossKind) -> Graph {
        assert!(widths.len() >= 2, "need at least input and output widths");
        let n = widths.len() - 1;
        let layers = widths
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let act = if i + 1 < n {
                    Activation::Relu
                } else {
                    Activation::Identity
                };
                Dense::glorot(rng, w[0], w[1], act)
            })
            .collect();
        Graph::new(layers, loss)
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    /// `[n_in, hidden..., n_out]`.
    pub fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.layers.iter().map(|l| l.fan_in()).collect();
        w.push(self.layers.last().unwrap().fan_out());
        w
    }

    /// Plain forward (serial whole-batch; borrows the input).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut h: Option<Matrix> = None;
        for layer in &self.layers {
            let prev = h.as_ref().unwrap_or(x);
            h = Some(layer.forward(prev));
        }
        h.expect("graph has at least one layer")
    }

    /// Validation loss + accuracy (serial case of [`Graph::evaluate_exec`]).
    pub fn evaluate(&self, x: &Matrix, y: &Matrix) -> (f32, f32) {
        self.evaluate_exec(x, y, &Executor::serial())
    }

    /// Validation, data-parallel, with a throwaway workspace — the cold
    /// path. Long-lived surfaces call [`Graph::evaluate_ws`] on a
    /// persistent workspace instead (same code, zero steady-state
    /// allocations); the two are bit-identical by construction.
    pub fn evaluate_exec(&self, x: &Matrix, y: &Matrix, exec: &Executor) -> (f32, f32) {
        let mut ws = GraphWorkspace::new(self, x.rows());
        self.evaluate_ws(x, y, exec, &mut ws)
    }

    /// Validation on a caller-owned workspace (§Perf pass): row-sharded
    /// forward through every layer into the workspace's activation
    /// buffers, then per-shard partial losses and (integer, hence
    /// exactly order-free) argmax-agreement counts reduced in fixed
    /// shard order. Zero allocations in steady state for any
    /// `m ≤ ws.batch()` — smaller eval batches run on a prefix of the
    /// buffers and shard slots; a larger batch (or a different graph
    /// shape) re-keys the workspace once.
    ///
    /// Evaluation is forward-only and always exact: activations land in
    /// each trace buffer's exact (staging) matrix and no codes are
    /// encoded. That **clobbers the training forward trace**, so
    /// long-lived trainers keep a dedicated eval workspace
    /// (`NativeTrainer`) rather than sharing the step workspace.
    pub fn evaluate_ws(
        &self,
        x: &Matrix,
        y: &Matrix,
        exec: &Executor,
        ws: &mut GraphWorkspace,
    ) -> (f32, f32) {
        let m = x.rows();
        assert!(m > 0, "evaluate needs a non-empty batch");
        assert_eq!(x.cols(), self.layers[0].fan_in(), "input dim vs first layer");
        ws.ensure(self, m.max(ws.batch()));
        let plan = exec.plan(m);
        let n_shards = plan.len();
        let n = self.layers.len();
        for (li, layer) in self.layers.iter().enumerate() {
            // warm the transpose cache outside the dispatch (narrow
            // shapes only — wide layers never read it), so the
            // narrow-B forward never transposes per shard
            let w_t = layer.warmed_w_t();
            let (before, rest) = ws.acts.split_at_mut(li);
            // rows m.. of the buffers are never written or read — the
            // forward and the loss head both stop at the eval batch
            let prev: &Matrix = if li == 0 { x } else { before[li - 1].exact() };
            let h = rest[0].exact_mut();
            let cols = h.cols();
            let hb = shard::RowBlocks::of_slice(&mut h.data_mut()[..m * cols], cols, &plan);
            exec.run_each(&plan, |i, rows| {
                // SAFETY: run_each claims each shard index exactly once
                let blk = unsafe { hb.block(i) };
                match w_t {
                    Some(t) => shard::forward_rows_bt(prev, &layer.w, t, &layer.b, rows, blk),
                    None => shard::forward_rows(prev, &layer.w, &layer.b, rows, blk),
                }
                layer.activation.apply_block(blk);
            });
        }
        let out = ws.acts[n - 1].exact();
        let p = out.cols();
        assert_eq!(y.shape(), (m, p), "target shape");
        {
            let loss_parts = &ws.loss_parts;
            exec.run_each(&plan, |i, rows| {
                let ob = shard::rows_of(out, rows.clone());
                let lp = self.loss.partial_loss(ob, y, rows.clone());
                *loss_parts[i].lock().unwrap() = (lp, loss::correct_rows(ob, y, rows));
            });
        }
        // fixed shard-order reduction — the same order the historical
        // `exec.map` + `reduce::sum_f32` pipeline produced, so results
        // stay bitwise identical to the pre-workspace eval
        let mut loss_total = 0.0f32;
        let mut correct = 0usize;
        for slot in ws.loss_parts.iter().take(n_shards) {
            let (l, c) = *slot.lock().unwrap();
            loss_total += l;
            correct += c;
        }
        (
            self.loss.finish_loss(loss_total, m, p),
            correct as f32 / m as f32,
        )
    }
}

/// Per-layer training state: the resolved config plus the layer's
/// error-feedback memory. Memory-off layers hold a storage-free
/// [`MemoryState::disabled`] — nothing is allocated that the step would
/// never read.
#[derive(Debug, Clone)]
pub struct LayerState {
    pub cfg: AopLayerConfig,
    pub mem: MemoryState,
}

/// The whole graph's Mem-AOP-GD state, one [`LayerState`] per layer.
#[derive(Debug, Clone)]
pub struct GraphState {
    pub layers: Vec<LayerState>,
}

impl GraphState {
    /// Build from resolved per-layer configs (one per graph layer).
    pub fn from_configs(graph: &Graph, batch: usize, cfgs: &[AopLayerConfig]) -> GraphState {
        assert_eq!(
            cfgs.len(),
            graph.layers.len(),
            "one AopLayerConfig per layer"
        );
        let layers = graph
            .layers
            .iter()
            .zip(cfgs.iter())
            .map(|(l, c)| LayerState {
                cfg: *c,
                mem: if c.memory {
                    MemoryState::new(batch, l.fan_in(), l.fan_out(), true)
                } else {
                    MemoryState::disabled()
                },
            })
            .collect();
        GraphState { layers }
    }

    /// Flat (homogeneous) config: the same `{k, policy, memory}` at every
    /// layer — the pre-layer-graph behavior.
    pub fn uniform(
        graph: &Graph,
        batch: usize,
        policy: Policy,
        k: usize,
        memory: bool,
    ) -> GraphState {
        let cfg = AopLayerConfig { k, policy, memory };
        let cfgs = vec![cfg; graph.layers.len()];
        GraphState::from_configs(graph, batch, &cfgs)
    }

    /// Exact-BP state: every row selected, memories off — nothing
    /// allocated. Backs the plain SGD step.
    pub fn exact(graph: &Graph, batch: usize) -> GraphState {
        GraphState::uniform(graph, batch, Policy::Exact, batch, false)
    }

    /// Frobenius mass deferred across all layer memories (the curves'
    /// `mem_fro`; for one layer this is exactly the single memory's
    /// `deferred_mass`).
    pub fn deferred_mass(&self) -> f32 {
        self.layers
            .iter()
            .map(|l| l.mem.deferred_sq())
            .sum::<f32>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_mlp_builds_and_forwards() {
        let mut rng = Rng::new(0);
        let g = Graph::relu_mlp(&mut rng, &[8, 16, 4], LossKind::SoftmaxCrossEntropy);
        assert_eq!(g.layers.len(), 2);
        assert_eq!(g.layers[0].activation, Activation::Relu);
        assert_eq!(g.layers[1].activation, Activation::Identity);
        assert_eq!(g.widths(), vec![8, 16, 4]);
        assert_eq!(g.num_params(), 8 * 16 + 16 + 16 * 4 + 4);
        let x = Matrix::from_fn(5, 8, |_, _| rng.normal());
        assert_eq!(g.forward(&x).shape(), (5, 4));
    }

    #[test]
    fn evaluate_exec_matches_serial_bitwise() {
        let mut rng = Rng::new(1);
        let g = Graph::relu_mlp(&mut rng, &[6, 11, 3], LossKind::SoftmaxCrossEntropy);
        let x = Matrix::from_fn(33, 6, |_, _| rng.normal());
        let y = Matrix::from_fn(33, 3, |r, c| ((r % 3) == c) as u32 as f32);
        let (l1, a1) = g.evaluate(&x, &y);
        let ex = Executor::new(4);
        let (l4, a4) = g.evaluate_exec(&x, &y, &ex);
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(a1, a4);
    }

    #[test]
    fn evaluate_ws_reuses_buffers_and_matches_throwaway_bitwise() {
        use crate::tensor::quant::{AccumMode, LayerPrecision, TraceMode};
        let mut rng = Rng::new(4);
        let g = Graph::relu_mlp(&mut rng, &[6, 11, 3], LossKind::SoftmaxCrossEntropy);
        let mk_batch = |rng: &mut Rng, m: usize| {
            let x = Matrix::from_fn(m, 6, |_, _| rng.normal());
            let y = Matrix::from_fn(m, 3, |r, c| ((r % 3) == c) as u32 as f32);
            (x, y)
        };
        let (x33, y33) = mk_batch(&mut rng, 33);
        let (x17, y17) = mk_batch(&mut rng, 17);
        let exec = Executor::serial();
        let mut ws = GraphWorkspace::new(&g, 33);
        // full-batch eval on the workspace == throwaway path bitwise
        let (le, ae) = g.evaluate_exec(&x33, &y33, &exec);
        let (lw, aw) = g.evaluate_ws(&x33, &y33, &exec, &mut ws);
        assert_eq!(le.to_bits(), lw.to_bits());
        assert_eq!(ae, aw);
        // a smaller batch runs on a prefix without re-keying
        assert_eq!(ws.batch(), 33);
        let (ls, asr) = g.evaluate_ws(&x17, &y17, &exec, &mut ws);
        assert_eq!(ws.batch(), 33, "prefix eval must not re-key");
        let (lse, ase) = g.evaluate_exec(&x17, &y17, &exec);
        assert_eq!(ls.to_bits(), lse.to_bits());
        assert_eq!(asr, ase);
        // quantized trace buffers evaluate through their exact staging
        // matrices — eval is forward-exact, so results don't move
        ws.set_precision(
            &g,
            &[LayerPrecision { trace: TraceMode::Q8, accum: AccumMode::F32 }; 2],
        );
        let (lq, aq) = g.evaluate_ws(&x33, &y33, &exec, &mut ws);
        assert_eq!(lq.to_bits(), le.to_bits(), "eval ignores trace quantization");
        assert_eq!(aq, ae);
        // a larger batch re-keys once and still matches
        let (x48, y48) = mk_batch(&mut rng, 48);
        let (ll, al) = g.evaluate_ws(&x48, &y48, &exec, &mut ws);
        assert_eq!(ws.batch(), 48);
        let (lle, ale) = g.evaluate_exec(&x48, &y48, &exec);
        assert_eq!(ll.to_bits(), lle.to_bits());
        assert_eq!(al, ale);
    }

    #[test]
    fn state_constructors_respect_memory_flags() {
        let mut rng = Rng::new(2);
        let g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::Mse);
        let on = GraphState::uniform(&g, 8, Policy::TopK, 3, true);
        assert!(on.layers.iter().all(|l| l.mem.enabled));
        assert_eq!(on.layers[0].mem.mem_x.shape(), (8, 4));
        assert_eq!(on.layers[1].mem.mem_g.shape(), (8, 2));
        let off = GraphState::exact(&g, 8);
        assert!(off.layers.iter().all(|l| !l.mem.enabled));
        // the satellite guarantee: no storage behind disabled memories
        assert!(off.layers.iter().all(|l| l.mem.mem_x.shape() == (0, 0)));
        assert_eq!(off.deferred_mass(), 0.0);
        assert_eq!(off.layers[0].cfg.k, 8);
        assert_eq!(off.layers[0].cfg.policy, Policy::Exact);
    }

    #[test]
    fn heterogeneous_configs_resolve_per_layer() {
        let mut rng = Rng::new(3);
        let g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::Mse);
        let cfgs = [
            AopLayerConfig { k: 2, policy: Policy::TopK, memory: true },
            AopLayerConfig { k: 5, policy: Policy::RandK, memory: false },
        ];
        let st = GraphState::from_configs(&g, 8, &cfgs);
        assert_eq!(st.layers[0].cfg.k, 2);
        assert_eq!(st.layers[1].cfg.policy, Policy::RandK);
        assert!(st.layers[0].mem.enabled);
        assert!(!st.layers[1].mem.enabled);
    }
}
