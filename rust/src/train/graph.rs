//! The layer graph: an ordered chain of [`Dense`] layers with one loss
//! head, plus the per-layer training state ([`GraphState`]) that pairs
//! each layer with its [`AopLayerConfig`] and error-feedback memory.
//!
//! This is the one model type every training surface shares: the paper's
//! single dense layer is a 1-layer identity-activation graph
//! (`AopEngine`), the MLP is a relu-hidden graph (`model::mlp`), and the
//! coordinator builds graphs straight from `ExperimentConfig`.

use crate::aop::{MemoryState, Policy};
use crate::exec::{reduce, shard, Executor};
use crate::model::activations::Activation;
use crate::model::loss::{self, LossKind};
use crate::tensor::{rng::Rng, Matrix};

use crate::train::layer::{AopLayerConfig, Dense};

/// A feed-forward chain of dense layers trained with Mem-AOP-GD.
#[derive(Debug, Clone)]
pub struct Graph {
    pub layers: Vec<Dense>,
    pub loss: LossKind,
}

impl Graph {
    /// Build from explicit layers; dims must chain.
    pub fn new(layers: Vec<Dense>, loss: LossKind) -> Graph {
        assert!(!layers.is_empty(), "a graph needs at least one layer");
        for win in layers.windows(2) {
            assert_eq!(
                win[0].fan_out(),
                win[1].fan_in(),
                "layer dims must chain: {} -> {}",
                win[0].fan_out(),
                win[1].fan_in()
            );
        }
        Graph { layers, loss }
    }

    /// The paper's single dense layer: one identity-activation `Dense`
    /// wrapping `w` with zero bias.
    pub fn single(w: Matrix, loss: LossKind) -> Graph {
        Graph::new(vec![Dense::from_weights(w, Activation::Identity)], loss)
    }

    /// Classic MLP over `widths` (e.g. `[784, 1024, 1024, 10]`): glorot
    /// init, relu hidden layers, identity head.
    pub fn relu_mlp(rng: &mut Rng, widths: &[usize], loss: LossKind) -> Graph {
        assert!(widths.len() >= 2, "need at least input and output widths");
        let n = widths.len() - 1;
        let layers = widths
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let act = if i + 1 < n {
                    Activation::Relu
                } else {
                    Activation::Identity
                };
                Dense::glorot(rng, w[0], w[1], act)
            })
            .collect();
        Graph::new(layers, loss)
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    /// `[n_in, hidden..., n_out]`.
    pub fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.layers.iter().map(|l| l.fan_in()).collect();
        w.push(self.layers.last().unwrap().fan_out());
        w
    }

    /// Plain forward (serial whole-batch; borrows the input).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut h: Option<Matrix> = None;
        for layer in &self.layers {
            let prev = h.as_ref().unwrap_or(x);
            h = Some(layer.forward(prev));
        }
        h.expect("graph has at least one layer")
    }

    /// Validation loss + accuracy (serial case of [`Graph::evaluate_exec`]).
    pub fn evaluate(&self, x: &Matrix, y: &Matrix) -> (f32, f32) {
        self.evaluate_exec(x, y, &Executor::serial())
    }

    /// Validation, data-parallel: row-sharded forward through every
    /// layer, then per-shard partial losses and (integer, hence exactly
    /// order-free) argmax-agreement counts reduced in fixed shard order.
    pub fn evaluate_exec(&self, x: &Matrix, y: &Matrix, exec: &Executor) -> (f32, f32) {
        let m = x.rows();
        let plan = exec.plan(m);
        // rolling buffer: evaluation needs only the previous layer's
        // output (unlike the training trace, which keeps every layer's
        // activation for the backward sweep)
        let mut prev: Option<Matrix> = None;
        for layer in &self.layers {
            let mut h = Matrix::zeros(m, layer.fan_out());
            {
                let pin: &Matrix = prev.as_ref().unwrap_or(x);
                // warm the transpose cache outside the dispatch (narrow
                // shapes only — wide layers never read it), so the
                // narrow-B forward never transposes per shard
                let w_t = layer.warmed_w_t();
                let hb = shard::RowBlocks::of(&mut h, &plan);
                exec.run_each(&plan, |i, rows| {
                    // SAFETY: run_each claims each shard index exactly once
                    let blk = unsafe { hb.block(i) };
                    match w_t {
                        Some(t) => shard::forward_rows_bt(pin, &layer.w, t, &layer.b, rows, blk),
                        None => shard::forward_rows(pin, &layer.w, &layer.b, rows, blk),
                    }
                    layer.activation.apply_block(blk);
                });
            }
            prev = Some(h);
        }
        let out = &prev.expect("graph has at least one layer");
        let p = out.cols();
        let parts: Vec<(f32, usize)> = exec.map(&plan, |_, rows| {
            let ob = shard::rows_of(out, rows.clone());
            (
                self.loss.partial_loss(ob, y, rows.clone()),
                loss::correct_rows(ob, y, rows),
            )
        });
        let loss_total = reduce::sum_f32(parts.iter().map(|(l, _)| *l));
        let correct = reduce::sum_usize(parts.iter().map(|(_, c)| *c));
        (
            self.loss.finish_loss(loss_total, m, p),
            correct as f32 / m as f32,
        )
    }
}

/// Per-layer training state: the resolved config plus the layer's
/// error-feedback memory. Memory-off layers hold a storage-free
/// [`MemoryState::disabled`] — nothing is allocated that the step would
/// never read.
#[derive(Debug, Clone)]
pub struct LayerState {
    pub cfg: AopLayerConfig,
    pub mem: MemoryState,
}

/// The whole graph's Mem-AOP-GD state, one [`LayerState`] per layer.
#[derive(Debug, Clone)]
pub struct GraphState {
    pub layers: Vec<LayerState>,
}

impl GraphState {
    /// Build from resolved per-layer configs (one per graph layer).
    pub fn from_configs(graph: &Graph, batch: usize, cfgs: &[AopLayerConfig]) -> GraphState {
        assert_eq!(
            cfgs.len(),
            graph.layers.len(),
            "one AopLayerConfig per layer"
        );
        let layers = graph
            .layers
            .iter()
            .zip(cfgs.iter())
            .map(|(l, c)| LayerState {
                cfg: *c,
                mem: if c.memory {
                    MemoryState::new(batch, l.fan_in(), l.fan_out(), true)
                } else {
                    MemoryState::disabled()
                },
            })
            .collect();
        GraphState { layers }
    }

    /// Flat (homogeneous) config: the same `{k, policy, memory}` at every
    /// layer — the pre-layer-graph behavior.
    pub fn uniform(
        graph: &Graph,
        batch: usize,
        policy: Policy,
        k: usize,
        memory: bool,
    ) -> GraphState {
        let cfg = AopLayerConfig { k, policy, memory };
        let cfgs = vec![cfg; graph.layers.len()];
        GraphState::from_configs(graph, batch, &cfgs)
    }

    /// Exact-BP state: every row selected, memories off — nothing
    /// allocated. Backs the plain SGD step.
    pub fn exact(graph: &Graph, batch: usize) -> GraphState {
        GraphState::uniform(graph, batch, Policy::Exact, batch, false)
    }

    /// Frobenius mass deferred across all layer memories (the curves'
    /// `mem_fro`; for one layer this is exactly the single memory's
    /// `deferred_mass`).
    pub fn deferred_mass(&self) -> f32 {
        self.layers
            .iter()
            .map(|l| l.mem.deferred_sq())
            .sum::<f32>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_mlp_builds_and_forwards() {
        let mut rng = Rng::new(0);
        let g = Graph::relu_mlp(&mut rng, &[8, 16, 4], LossKind::SoftmaxCrossEntropy);
        assert_eq!(g.layers.len(), 2);
        assert_eq!(g.layers[0].activation, Activation::Relu);
        assert_eq!(g.layers[1].activation, Activation::Identity);
        assert_eq!(g.widths(), vec![8, 16, 4]);
        assert_eq!(g.num_params(), 8 * 16 + 16 + 16 * 4 + 4);
        let x = Matrix::from_fn(5, 8, |_, _| rng.normal());
        assert_eq!(g.forward(&x).shape(), (5, 4));
    }

    #[test]
    fn evaluate_exec_matches_serial_bitwise() {
        let mut rng = Rng::new(1);
        let g = Graph::relu_mlp(&mut rng, &[6, 11, 3], LossKind::SoftmaxCrossEntropy);
        let x = Matrix::from_fn(33, 6, |_, _| rng.normal());
        let y = Matrix::from_fn(33, 3, |r, c| ((r % 3) == c) as u32 as f32);
        let (l1, a1) = g.evaluate(&x, &y);
        let ex = Executor::new(4);
        let (l4, a4) = g.evaluate_exec(&x, &y, &ex);
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(a1, a4);
    }

    #[test]
    fn state_constructors_respect_memory_flags() {
        let mut rng = Rng::new(2);
        let g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::Mse);
        let on = GraphState::uniform(&g, 8, Policy::TopK, 3, true);
        assert!(on.layers.iter().all(|l| l.mem.enabled));
        assert_eq!(on.layers[0].mem.mem_x.shape(), (8, 4));
        assert_eq!(on.layers[1].mem.mem_g.shape(), (8, 2));
        let off = GraphState::exact(&g, 8);
        assert!(off.layers.iter().all(|l| !l.mem.enabled));
        // the satellite guarantee: no storage behind disabled memories
        assert!(off.layers.iter().all(|l| l.mem.mem_x.shape() == (0, 0)));
        assert_eq!(off.deferred_mass(), 0.0);
        assert_eq!(off.layers[0].cfg.k, 8);
        assert_eq!(off.layers[0].cfg.policy, Policy::Exact);
    }

    #[test]
    fn heterogeneous_configs_resolve_per_layer() {
        let mut rng = Rng::new(3);
        let g = Graph::relu_mlp(&mut rng, &[4, 6, 2], LossKind::Mse);
        let cfgs = [
            AopLayerConfig { k: 2, policy: Policy::TopK, memory: true },
            AopLayerConfig { k: 5, policy: Policy::RandK, memory: false },
        ];
        let st = GraphState::from_configs(&g, 8, &cfgs);
        assert_eq!(st.layers[0].cfg.k, 2);
        assert_eq!(st.layers[1].cfg.policy, Policy::RandK);
        assert!(st.layers[0].mem.enabled);
        assert!(!st.layers[1].mem.enabled);
    }
}
