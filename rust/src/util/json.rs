//! Minimal JSON parser / serializer.
//!
//! The build environment is fully offline (no `serde_json`), so the
//! artifact manifest, experiment configs and metric sinks use this
//! in-tree implementation. It supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null) and preserves
//! object insertion order, which keeps emitted configs diff-friendly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep (key, value) pairs in insertion order.
    Obj(Vec<(String, Json)>),
}

/// Error raised by [`parse`].
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mandatory object field.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing field '{key}'"),
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `[1,2,3]` -> `vec![1,2,3]` for shape fields.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly (no whitespace).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with `indent` spaces per level.
    pub fn pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(indent), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in): (String, String, String) = match indent {
            Some(i) => (
                "\n".into(),
                " ".repeat(i * depth),
                " ".repeat(i * (depth + 1)),
            ),
            None => (String::new(), String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad_in);
                    it.write(out, indent, depth + 1);
                }
                out.push_str(&nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(&nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for emitting JSON without intermediate maps.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num<N: Into<f64>>(n: N) -> Json {
    Json::Num(n.into())
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let v = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(v).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let sl = &self.bytes[start..start + len];
                        let st = std::str::from_utf8(sl)
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(st);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Parse into a string-keyed map (for flat config objects).
pub fn to_map(v: &Json) -> Option<BTreeMap<String, Json>> {
    v.as_obj()
        .map(|pairs| pairs.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize().unwrap(), 1);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"x","shape":[2,3],"ok":true,"v":1.5}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.dump()).unwrap(), v);
        assert_eq!(parse(&v.pretty(2)).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_and_surrogates() {
        assert_eq!(
            parse(r#""é 😀 é""#).unwrap(),
            Json::Str("é 😀 é".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn preserves_object_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn usize_vec() {
        assert_eq!(
            parse("[144,16]").unwrap().as_usize_vec().unwrap(),
            vec![144, 16]
        );
        assert!(parse("[1,-2]").unwrap().as_usize_vec().is_none());
        assert!(parse("[1,2.5]").unwrap().as_usize_vec().is_none());
    }

    #[test]
    fn builders() {
        let v = obj(vec![("k", arr([num(1), num(2)])), ("s", s("t"))]);
        assert_eq!(v.dump(), r#"{"k":[1,2],"s":"t"}"#);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(144.0).dump(), "144");
        assert_eq!(Json::Num(0.25).dump(), "0.25");
    }
}
