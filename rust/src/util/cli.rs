//! Tiny declarative CLI argument parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, and generates `--help` text. Only what the `repro`
//! binary and the examples need — but with real validation and error
//! messages, not ad-hoc `args().nth()` poking.

use std::collections::BTreeMap;
use std::fmt;

/// Declaration of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None ⇒ boolean flag; Some(default) ⇒ takes a value.
    pub default: Option<String>,
    pub required: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Leftover positional arguments.
    pub positional: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError(format!("missing --{name}")))?;
        raw.parse()
            .map_err(|e| CliError(format!("--{name}={raw}: {e}")))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// One subcommand with its option table.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            required: false,
        });
        self
    }

    /// Required `--name <value>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(String::new()),
            required: true,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            required: false,
        });
        self
    }

    fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let left = match &o.default {
                None => format!("  --{}", o.name),
                Some(_) if o.required => format!("  --{} <v> (required)", o.name),
                Some(d) if d.is_empty() => format!("  --{} <v>", o.name),
                Some(d) => format!("  --{} <v> [{}]", o.name, d),
            };
            out.push_str(&format!("{left:40} {}\n", o.help));
        }
        out
    }

    /// Parse a raw argv slice against this command's option table.
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            match &o.default {
                Some(d) if !o.required && !d.is_empty() => {
                    args.values.insert(o.name.to_string(), d.clone());
                }
                None => {
                    args.flags.insert(o.name.to_string(), false);
                }
                _ => {}
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}\n\n{}", self.usage())))?;
                match &spec.default {
                    None => {
                        if inline_val.is_some() {
                            return Err(CliError(format!("--{key} is a flag, takes no value")));
                        }
                        args.flags.insert(key.to_string(), true);
                    }
                    Some(_) => {
                        let v = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                            }
                        };
                        args.values.insert(key.to_string(), v);
                    }
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if o.required && !args.values.contains_key(o.name) {
                return Err(CliError(format!("missing required --{}\n\n{}", o.name, self.usage())));
            }
        }
        Ok(args)
    }
}

/// Top-level multi-command app.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\ncommands:\n", self.name, self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:20} {}\n", c.name, c.about));
        }
        out.push_str("\nrun `<command> --help` for per-command options\n");
        out
    }

    /// Dispatch: returns (command name, parsed args).
    pub fn parse(&self, argv: &[String]) -> Result<(&Command, Args), CliError> {
        let first = argv.first().ok_or_else(|| CliError(self.usage()))?;
        if first == "--help" || first == "-h" || first == "help" {
            return Err(CliError(self.usage()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == first)
            .ok_or_else(|| CliError(format!("unknown command '{first}'\n\n{}", self.usage())))?;
        let args = cmd.parse(&argv[1..])?;
        Ok((cmd, args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("epochs", "100", "number of epochs")
            .opt("policy", "topk", "selection policy")
            .req("task", "task name")
            .flag("no-memory", "disable error feedback")
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&["--task", "energy"])).unwrap();
        assert_eq!(a.get("epochs"), Some("100"));
        assert_eq!(a.get_parse::<usize>("epochs").unwrap(), 100);
        assert!(!a.flag("no-memory"));
    }

    #[test]
    fn equals_and_space_forms() {
        let a = cmd()
            .parse(&argv(&["--task=mnist", "--epochs", "7", "--no-memory"]))
            .unwrap();
        assert_eq!(a.get("task"), Some("mnist"));
        assert_eq!(a.get("epochs"), Some("7"));
        assert!(a.flag("no-memory"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cmd().parse(&argv(&["--epochs", "3"])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&argv(&["--task", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--task", "x", "--no-memory=1"])).is_err());
    }

    #[test]
    fn value_missing_rejected() {
        assert!(cmd().parse(&argv(&["--task"])).is_err());
    }

    #[test]
    fn bad_parse_type() {
        let a = cmd().parse(&argv(&["--task", "x", "--epochs", "abc"])).unwrap();
        assert!(a.get_parse::<usize>("epochs").is_err());
    }

    #[test]
    fn app_dispatch() {
        let app = App {
            name: "repro",
            about: "Mem-AOP-GD reproduction",
            commands: vec![cmd(), Command::new("table", "print Tab. I")],
        };
        let (c, a) = app.parse(&argv(&["train", "--task", "energy"])).unwrap();
        assert_eq!(c.name, "train");
        assert_eq!(a.get("task"), Some("energy"));
        assert!(app.parse(&argv(&["bogus"])).is_err());
        assert!(app.parse(&argv(&["help"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&argv(&["--task", "x", "pos1", "pos2"])).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }
}
