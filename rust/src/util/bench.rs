//! Criterion-style micro/macro benchmark harness (offline substitute).
//!
//! `cargo bench` targets in `rust/benches/` are plain `main`s
//! (`harness = false`) built on this module: warmup, adaptive iteration
//! count, robust statistics (median / p10 / p90 / MAD), and a
//! machine-readable JSONL sink under `results/bench/` so the figure
//! harness and EXPERIMENTS.md can quote numbers verbatim.

// Clock reads are deliberate here (benchmark timing is this module's purpose) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// Robust summary of one benchmark's per-iteration timings.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// Median absolute deviation (scaled to ns).
    pub mad_ns: f64,
    /// Optional caller-supplied work metric (e.g. FLOPs per iteration).
    pub work_per_iter: Option<f64>,
}

impl Stats {
    /// Work metric per second from the median iteration time.
    pub fn work_rate(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / (self.median_ns * 1e-9))
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", json::s(&self.name)),
            ("iters", json::num(self.iters as f64)),
            ("median_ns", json::num(self.median_ns)),
            ("mean_ns", json::num(self.mean_ns)),
            ("p10_ns", json::num(self.p10_ns)),
            ("p90_ns", json::num(self.p90_ns)),
            ("mad_ns", json::num(self.mad_ns)),
        ];
        if let Some(w) = self.work_per_iter {
            pairs.push(("work_per_iter", json::num(w)));
            pairs.push(("work_per_sec", json::num(self.work_rate().unwrap())));
        }
        json::obj(pairs)
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bencher {
    /// Target measurement time per benchmark.
    pub measure: Duration,
    /// Warmup time per benchmark.
    pub warmup: Duration,
    results: Vec<Stats>,
    suite: String,
}

impl Bencher {
    pub fn new(suite: &str) -> Self {
        // Respect a quick mode for CI-ish runs: BENCH_QUICK=1
        let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
        Bencher {
            measure: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_millis(1500)
            },
            warmup: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            results: Vec::new(),
            suite: suite.to_string(),
        }
    }

    /// Time `f` repeatedly; `f` must perform one unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> Stats {
        self.bench_with_work(name, None, f)
    }

    /// Like [`bench`] but records a work metric (e.g. FLOPs) per iteration.
    pub fn bench_with_work<F: FnMut()>(
        &mut self,
        name: &str,
        work_per_iter: Option<f64>,
        mut f: F,
    ) -> Stats {
        // Warmup and calibration: figure out how many calls fit in a batch.
        let warm_start = Instant::now();
        let mut calls_in_warmup = 0usize;
        while warm_start.elapsed() < self.warmup {
            f();
            calls_in_warmup += 1;
        }
        let per_call = self.warmup.as_secs_f64() / calls_in_warmup.max(1) as f64;
        // Aim for ~50 samples; batch calls if each is very fast.
        let batch = ((self.measure.as_secs_f64() / 50.0) / per_call.max(1e-9))
            .max(1.0)
            .min(1e7) as usize;

        let mut samples_ns: Vec<f64> = Vec::new();
        let meas_start = Instant::now();
        let mut total_calls = 0usize;
        while meas_start.elapsed() < self.measure || samples_ns.len() < 5 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(ns);
            total_calls += batch;
            if samples_ns.len() > 5000 {
                break;
            }
        }
        let stats = summarize(name, total_calls, &mut samples_ns, work_per_iter);
        eprintln!(
            "{:44} {:>12}  (p10 {} / p90 {}, {} iters)",
            stats.name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.p10_ns),
            fmt_ns(stats.p90_ns),
            stats.iters
        );
        if let Some(rate) = stats.work_rate() {
            eprintln!("{:44} {:>12.3e} work-units/s", "", rate);
        }
        self.results.push(stats.clone());
        stats
    }

    /// Write all collected results as JSONL under `results/bench/`.
    pub fn finish(self) {
        let dir = std::path::Path::new("results/bench");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let path = dir.join(format!("{}.jsonl", self.suite));
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&r.to_json().dump());
            out.push('\n');
        }
        let _ = std::fs::write(&path, out);
        eprintln!("[bench] wrote {} results to {}", self.results.len(), path.display());
    }
}

fn summarize(
    name: &str,
    iters: usize,
    samples: &mut [f64],
    work_per_iter: Option<f64>,
) -> Stats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| -> f64 {
        let idx = (p * (samples.len() - 1) as f64).round() as usize;
        samples[idx]
    };
    let median = q(0.5);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    Stats {
        name: name.to_string(),
        iters,
        median_ns: median,
        mean_ns: mean,
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        mad_ns: mad,
        work_per_iter,
    }
}

/// Human duration formatting for ns quantities.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let mut b = Bencher::new("test");
        b.measure = Duration::from_millis(30);
        b.warmup = Duration::from_millis(5);
        let mut acc = 0u64;
        let s = b.bench("spin", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert!(s.iters > 0);
        assert!(s.median_ns > 0.0);
    }

    #[test]
    fn work_rate_computed() {
        let s = Stats {
            name: "x".into(),
            iters: 10,
            median_ns: 1e6,
            mean_ns: 1e6,
            p10_ns: 1e6,
            p90_ns: 1e6,
            mad_ns: 0.0,
            work_per_iter: Some(2e6),
        };
        let r = s.work_rate().unwrap();
        assert!((r - 2e9).abs() / 2e9 < 1e-9);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
