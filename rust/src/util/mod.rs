//! In-tree substrates replacing crates unavailable in the offline build
//! environment (see the note in `Cargo.toml`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
