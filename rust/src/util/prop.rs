//! Property-testing mini-framework (offline substitute for `proptest`).
//!
//! A property is a closure over a [`Gen`] (seeded RNG-backed value source);
//! the runner executes it across many random cases and, on failure,
//! re-runs with the failing seed reported so the case is reproducible:
//!
//! ```no_run
//! use mem_aop_gd::util::prop::{property, Gen};
//! property("abs is non-negative", 200, |g: &mut Gen| {
//!     let x = g.f32_range(-10.0, 10.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```
//!
//! Coordinator invariants (routing, batching, selection, memory state) are
//! verified through this runner in `rust/tests/props.rs` and in per-module
//! `#[cfg(test)]` blocks.

use crate::tensor::rng::Rng;

/// Value generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Seed of the current case (for failure reports).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            case_seed: seed,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() % (hi - lo + 1) as u64) as usize
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.uniform()
    }

    pub fn f32_normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of standard normals.
    pub fn vec_normal(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn vec_uniform(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(lo, hi)).collect()
    }

    /// A 0/1 mask with each entry independently 1 w.p. `p`.
    pub fn mask(&mut self, n: usize, p: f32) -> Vec<f32> {
        (0..n)
            .map(|_| if self.rng.uniform() < p { 1.0 } else { 0.0 })
            .collect()
    }

    /// Borrow the underlying RNG (for passing to library APIs under test).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `body`. Panics (with the case seed) on the
/// first failing case. The base seed is fixed for reproducibility but can
/// be overridden with the `PROP_SEED` env var.
pub fn property<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut body: F) {
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x9E3779B97F4A7C15u64);
    for i in 0..cases {
        let seed = base.wrapping_add((i as u64).wrapping_mul(0xA24BAED4963EE407));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}):\n{msg}\n\
                 reproduce with PROP_SEED={base} and case index {i}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        property("sum symmetric", 100, |g| {
            let a = g.f32_range(-5.0, 5.0);
            let b = g.f32_range(-5.0, 5.0);
            assert!((a + b - (b + a)).abs() < 1e-6);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failing_case() {
        property("always fails", 10, |g| {
            let x = g.f32_range(0.0, 1.0);
            assert!(x < 0.0, "x={x}");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        property("ranges", 200, |g| {
            let n = g.usize_range(1, 64);
            assert!((1..=64).contains(&n));
            let f = g.f32_range(2.0, 3.0);
            assert!((2.0..3.0001).contains(&f));
            let m = g.mask(n, 0.5);
            assert!(m.iter().all(|&v| v == 0.0 || v == 1.0));
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        property("record", 5, |g| first.push(g.u64()));
        let mut second: Vec<u64> = Vec::new();
        property("record", 5, |g| second.push(g.u64()));
        assert_eq!(first, second);
    }
}
