//! Scoped worker pool for parallel experiment sweeps (offline substitute
//! for tokio/rayon on the coordinator's *control* plane).
//!
//! The figure harness runs dozens of independent training runs (7 series ×
//! 3 compression levels × seeds); [`run_parallel`] fans them out over
//! `std::thread::scope` with a bounded worker count and returns results in
//! input order. Work items must be `Send`; panics in a worker are
//! propagated to the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers: `REPRO_THREADS` env override, else available
/// parallelism, else 4.
pub fn default_workers() -> usize {
    std::env::var("REPRO_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

/// Apply `f` to every item of `items` on up to `workers` threads,
/// preserving input order in the returned vector.
pub fn run_parallel<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|it| f(it)).collect();
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let next = &next;
            let slots = &slots;
            let items = &items;
            let f = &f;
            handles.push(scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            }));
        }
        for h in handles {
            // propagate worker panics
            h.join().expect("worker thread panicked");
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = run_parallel(items.clone(), 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = run_parallel(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = run_parallel(Vec::<i32>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let out = run_parallel(vec![10], 16, |&x| x - 1);
        assert_eq!(out, vec![9]);
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn worker_panic_propagates() {
        run_parallel(vec![0usize, 1], 2, |&x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn heavy_fanout_consistent() {
        let items: Vec<u64> = (0..500).collect();
        let out = run_parallel(items, 13, |&x| {
            // small unequal work per item
            (0..(x % 7 + 1)).sum::<u64>() + x
        });
        for (i, v) in out.iter().enumerate() {
            let x = i as u64;
            assert_eq!(*v, (0..(x % 7 + 1)).sum::<u64>() + x);
        }
    }
}
