//! Worker pools (offline substitute for tokio/rayon).
//!
//! Two shapes of parallelism live here:
//!
//! * [`run_parallel`] — a *scoped, one-shot* fan-out over
//!   `std::thread::scope` used by the figure harness and sweeps: apply a
//!   function to a finished list of items and return results in input
//!   order. Work items must be `Send`; panics in a worker are propagated
//!   to the caller.
//! * [`TaskPool`] — a *long-lived* condvar worker pool draining a FIFO of
//!   boxed tasks, used by the serve scheduler (`serve::queue`) for its
//!   coarse-grained jobs. The data-parallel execution engine keeps its
//!   own allocation-free job-slot pool (`exec::pool`) — boxing a task
//!   per shard dispatch is exactly the per-step heap traffic the §Perf
//!   pass removed. Shutdown is graceful: the queue is drained before
//!   the workers exit.

// Clock reads are deliberate here (condvar wait deadlines) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Number of workers: `REPRO_THREADS` env override, else available
/// parallelism, else 4.
pub fn default_workers() -> usize {
    std::env::var("REPRO_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

/// Apply `f` to every item of `items` on up to `workers` threads,
/// preserving input order in the returned vector.
pub fn run_parallel<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|it| f(it)).collect();
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let next = &next;
            let slots = &slots;
            let items = &items;
            let f = &f;
            handles.push(scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            }));
        }
        for h in handles {
            // propagate worker panics
            h.join().expect("worker thread panicked");
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

type Task = Box<dyn FnOnce() + Send>;

struct TaskShared {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Workers currently executing a task (obs gauge; relaxed — a
    /// metrics scrape may be one task off, never wrong by more).
    busy: AtomicUsize,
}

/// Long-lived FIFO worker pool: `workers` threads block on a condvar and
/// drain boxed tasks in submission order.
///
/// * submission after [`TaskPool::shutdown`] is refused (returns `false`);
/// * [`TaskPool::shutdown`] is graceful and idempotent: workers finish
///   every queued task, then exit and are joined — no accepted task is
///   ever dropped;
/// * a panicking task is caught and logged; the worker survives and keeps
///   draining (long-lived services must not lose workers to one bad job).
pub struct TaskPool {
    shared: Arc<TaskShared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
}

impl TaskPool {
    /// Spawn `workers` (≥1) threads named `<name>-<i>`.
    pub fn new(name: &str, workers: usize) -> TaskPool {
        let workers = workers.max(1);
        let shared = Arc::new(TaskShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            busy: AtomicUsize::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || task_loop(&sh))
                    .expect("spawning pool worker")
            })
            .collect();
        TaskPool {
            shared,
            handles: Mutex::new(handles),
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Tasks queued but not yet picked up by a worker.
    pub fn pending(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Workers currently executing a task (`0..=workers`).
    pub fn busy(&self) -> usize {
        self.shared.busy.load(Ordering::Relaxed)
    }

    pub fn is_shutdown(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Enqueue a task; returns `false` (task NOT queued) once shut down.
    /// The shutdown check happens under the queue lock — the same lock
    /// [`TaskPool::shutdown`] sets the flag under — so a `true` return
    /// means the push strictly preceded the flag and the drain covers
    /// it: an accepted task always runs.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        {
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return false;
            }
            q.push_back(Box::new(f));
        }
        self.shared.cv.notify_one();
        true
    }

    /// Liveness probe (serve health op): submit a no-op task and wait up
    /// to `timeout` for a worker to run it. `Some(latency)` proves the
    /// pool is alive and draining; `None` means it is shut down, or so
    /// saturated or wedged that nothing picked the probe up in time —
    /// the serve tier reports that as `degraded`. The probe task is a
    /// plain FIFO entry: it never jumps the queue, so the latency is an
    /// honest sample of current queue delay.
    pub fn probe(&self, timeout: std::time::Duration) -> Option<std::time::Duration> {
        let t0 = std::time::Instant::now();
        let done = Arc::new((Mutex::new(false), Condvar::new()));
        let signal = done.clone();
        if !self.submit(move || {
            let (flag, cv) = &*signal;
            *flag.lock().unwrap() = true;
            cv.notify_all();
        }) {
            return None;
        }
        let (flag, cv) = &*done;
        let mut ran = flag.lock().unwrap();
        while !*ran {
            let elapsed = t0.elapsed();
            if elapsed >= timeout {
                return None;
            }
            let (guard, _) = cv.wait_timeout(ran, timeout - elapsed).unwrap();
            ran = guard;
        }
        Some(t0.elapsed())
    }

    /// Refuse new tasks, drain the queue, join the workers. Idempotent.
    pub fn shutdown(&self) {
        {
            // flag flips under the queue lock so it totally orders with
            // every submit: anything accepted is already in the queue
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.cv.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn task_loop(sh: &TaskShared) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        let Some(task) = task else { return };
        // AssertUnwindSafe: the task owns its captures; a panicked task's
        // state is discarded with it, nothing half-mutated is observed.
        sh.busy.fetch_add(1, Ordering::Relaxed);
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)).is_err() {
            eprintln!("[pool] task panicked (worker continues)");
        }
        sh.busy.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = run_parallel(items.clone(), 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = run_parallel(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = run_parallel(Vec::<i32>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let out = run_parallel(vec![10], 16, |&x| x - 1);
        assert_eq!(out, vec![9]);
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn worker_panic_propagates() {
        run_parallel(vec![0usize, 1], 2, |&x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn task_pool_runs_everything_submitted() {
        let pool = TaskPool::new("t", 4);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let h = hits.clone();
            assert!(pool.submit(move || {
                h.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.shutdown();
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        // post-shutdown submissions are refused
        assert!(!pool.submit(|| {}));
        assert!(pool.is_shutdown());
    }

    #[test]
    fn task_pool_busy_gauge_tracks_running_tasks() {
        let pool = TaskPool::new("busy", 2);
        assert_eq!(pool.busy(), 0);
        let release = Arc::new(AtomicBool::new(false));
        let r = release.clone();
        pool.submit(move || {
            while !r.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        });
        // the gauge must reach 1 while the task is parked
        let mut saw_busy = false;
        for _ in 0..2000 {
            if pool.busy() == 1 {
                saw_busy = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(saw_busy, "busy gauge never observed the running task");
        release.store(true, Ordering::Relaxed);
        pool.shutdown();
        assert_eq!(pool.busy(), 0);
    }

    #[test]
    fn task_pool_shutdown_drains_queue() {
        // 1 worker, slow first task: the rest must still all run
        let pool = TaskPool::new("drain", 1);
        let hits = Arc::new(AtomicUsize::new(0));
        for i in 0..10 {
            let h = hits.clone();
            pool.submit(move || {
                if i == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                }
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn task_pool_survives_panicking_task() {
        let pool = TaskPool::new("p", 1);
        let hits = Arc::new(AtomicUsize::new(0));
        pool.submit(|| panic!("boom"));
        let h = hits.clone();
        pool.submit(move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        pool.shutdown();
        assert_eq!(hits.load(Ordering::Relaxed), 1, "worker died with the panic");
    }

    #[test]
    fn probe_round_trips_an_idle_pool_and_times_out_a_wedged_one() {
        use std::time::Duration;
        let pool = TaskPool::new("probe", 1);
        // idle pool: the probe comes back quickly
        let latency = pool.probe(Duration::from_secs(5)).expect("idle pool must answer");
        assert!(latency < Duration::from_secs(5));
        // wedge the single worker: the probe queues behind it and the
        // bounded wait reports the pool degraded instead of hanging
        let release = Arc::new(AtomicBool::new(false));
        let r = release.clone();
        pool.submit(move || {
            while !r.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        assert!(pool.probe(Duration::from_millis(50)).is_none(), "wedged pool answered");
        release.store(true, Ordering::Relaxed);
        pool.shutdown();
        // a shut-down pool refuses the probe outright
        assert!(pool.probe(Duration::from_millis(10)).is_none());
    }

    #[test]
    fn heavy_fanout_consistent() {
        let items: Vec<u64> = (0..500).collect();
        let out = run_parallel(items, 13, |&x| {
            // small unequal work per item
            (0..(x % 7 + 1)).sum::<u64>() + x
        });
        for (i, v) in out.iter().enumerate() {
            let x = i as u64;
            assert_eq!(*v, (0..(x % 7 + 1)).sum::<u64>() + x);
        }
    }
}
