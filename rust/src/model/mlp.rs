//! The multi-layer perceptron API — now an alias surface over the
//! layer-graph training core.
//!
//! The MLP *is* a [`Graph`](crate::train::Graph): relu hidden layers,
//! identity head, per-layer Mem-AOP-GD state. The step implementation
//! that used to live here (and its near-duplicate in `aop/engine.rs`)
//! moved to `train::step`; this module keeps the historical names and
//! the MLP-flavored convenience methods.

pub use crate::train::graph::Graph as Mlp;
pub use crate::train::layer::Dense as DenseLayer;
pub use crate::train::step::StepOutcome as MlpStepInfo;

use crate::exec::Executor;
use crate::tensor::{rng::Rng, Matrix};
use crate::train::{self, GraphState, StepOutcome};

impl Mlp {
    /// One Mem-AOP-GD train step (Algorithm 1 applied per layer) with
    /// per-layer state. Serial (`threads = 1`) case of
    /// [`Mlp::train_step_aop_exec`].
    pub fn train_step_aop(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        eta: f32,
        state: &mut GraphState,
        rng: &mut Rng,
    ) -> StepOutcome {
        self.train_step_aop_exec(x, y, eta, state, rng, &Executor::serial())
    }

    /// Data-parallel Mem-AOP-GD step (see `train::step::train_step`):
    /// bit-identical curves and weights at every thread count.
    pub fn train_step_aop_exec(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        eta: f32,
        state: &mut GraphState,
        rng: &mut Rng,
        exec: &Executor,
    ) -> StepOutcome {
        train::train_step(self, state, x, y, eta, rng, exec, true)
    }

    /// Exact SGD step (baseline comparator) — the Exact policy routed
    /// through the unified step with memories disabled; no memory
    /// matrices or RNG are constructed.
    pub fn train_step_sgd(&mut self, x: &Matrix, y: &Matrix, eta: f32) -> StepOutcome {
        train::train_step_exact(self, x, y, eta, &Executor::serial())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aop::Policy;
    use crate::model::loss::LossKind;

    #[test]
    fn mlp_alias_surface_works() {
        // the historical names resolve to the layer-graph types and the
        // MLP constructor still produces relu hiddens + identity head
        let mut rng = Rng::new(0);
        let mlp = Mlp::relu_mlp(&mut rng, &[8, 16, 4], LossKind::SoftmaxCrossEntropy);
        assert_eq!(mlp.layers.len(), 2);
        assert_eq!(mlp.num_params(), 8 * 16 + 16 + 16 * 4 + 4);
        assert_eq!(mlp.widths(), vec![8, 16, 4]);
        let layer: &DenseLayer = &mlp.layers[0];
        assert_eq!(layer.fan_in(), 8);
    }

    #[test]
    fn sgd_and_aop_steps_run_through_the_unified_core() {
        let mut rng = Rng::new(1);
        let mut mlp = Mlp::relu_mlp(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
        let x = Matrix::from_fn(12, 6, |_, _| rng.normal());
        let y = Matrix::from_fn(12, 3, |r, c| ((r % 3) == c) as u32 as f32);
        let before = mlp.evaluate(&x, &y).0;
        for _ in 0..20 {
            let info: MlpStepInfo = mlp.train_step_sgd(&x, &y, 0.1);
            assert!(info.loss.is_finite());
            assert_eq!(info.k_effective, 24); // exact: every row, each layer
        }
        let mut state = GraphState::uniform(&mlp, 12, Policy::TopK, 4, true);
        for _ in 0..20 {
            let info = mlp.train_step_aop(&x, &y, 0.1, &mut state, &mut rng);
            assert_eq!(info.k_effective, 8); // 4 per layer × 2 layers
        }
        let after = mlp.evaluate(&x, &y).0;
        assert!(after < before, "before={before} after={after}");
    }
}
