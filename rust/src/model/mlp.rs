//! Dense layers and the multi-layer perceptron used by the native trainer
//! and the end-to-end example.
//!
//! The MLP applies Mem-AOP-GD *per layer*: each dense weight gradient
//! `W_i* = X̂_i^T Ĝ_i` goes through the selection policy with its own
//! error-feedback memory, while the backward chain (eq. (2a)) uses the
//! exact pre-update weights — matching `python/compile/model.py`'s
//! `mlp_train_step` operation-for-operation.

use crate::aop::{policy, MemoryState, Policy};
use crate::exec::{reduce, shard, Executor};
use crate::model::activations::relu;
use crate::model::loss::{accuracy, LossKind};
use crate::tensor::rng::Rng;
use crate::tensor::{init, ops, Matrix};

/// One dense layer `o = x W + b`.
#[derive(Debug, Clone)]
pub struct DenseLayer {
    pub w: Matrix,
    pub b: Vec<f32>,
}

impl DenseLayer {
    /// Glorot-uniform weights, zero bias (Keras default).
    pub fn glorot(rng: &mut Rng, fan_in: usize, fan_out: usize) -> Self {
        DenseLayer {
            w: init::glorot_uniform(rng, fan_in, fan_out),
            b: init::zeros_bias(fan_out),
        }
    }

    pub fn forward(&self, x: &Matrix) -> Matrix {
        x.matmul(&self.w).add_row_broadcast(&self.b)
    }

    pub fn fan_in(&self) -> usize {
        self.w.rows()
    }

    pub fn fan_out(&self) -> usize {
        self.w.cols()
    }

    pub fn num_params(&self) -> usize {
        self.w.rows() * self.w.cols() + self.b.len()
    }
}

/// Multi-layer perceptron: relu hidden layers, linear head.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<DenseLayer>,
    pub loss: LossKind,
}

/// Per-layer AOP training state for an MLP.
pub struct MlpAopState {
    pub memories: Vec<MemoryState>,
    pub policy: Policy,
    pub k: usize,
}

/// Metrics from one MLP train step.
#[derive(Debug, Clone, Copy)]
pub struct MlpStepInfo {
    pub loss: f32,
    pub acc: f32,
    /// Total distinct outer products evaluated across layers.
    pub k_effective: usize,
}

impl Mlp {
    /// Build with the given layer widths, e.g. `[784, 1024, 1024, 10]`.
    pub fn new(rng: &mut Rng, widths: &[usize], loss: LossKind) -> Self {
        assert!(widths.len() >= 2, "need at least input and output widths");
        let layers = widths
            .windows(2)
            .map(|w| DenseLayer::glorot(rng, w[0], w[1]))
            .collect();
        Mlp { layers, loss }
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    pub fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.layers.iter().map(|l| l.fan_in()).collect();
        w.push(self.layers.last().unwrap().fan_out());
        w
    }

    /// Forward pass; returns per-layer inputs (`acts`, length L+1) and
    /// pre-activations (`zs`, length L).
    pub fn forward_trace(&self, x: &Matrix) -> (Vec<Matrix>, Vec<Matrix>) {
        let n = self.layers.len();
        let mut acts = Vec::with_capacity(n + 1);
        let mut zs = Vec::with_capacity(n);
        acts.push(x.clone());
        let mut h = x.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            let z = layer.forward(&h);
            h = if i + 1 < n { relu(&z) } else { z.clone() };
            zs.push(z);
            acts.push(h.clone());
        }
        (acts, zs)
    }

    /// Plain forward (no trace).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let n = self.layers.len();
        let mut h = x.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            let z = layer.forward(&h);
            h = if i + 1 < n { relu(&z) } else { z };
        }
        h
    }

    /// Validation loss + accuracy.
    pub fn evaluate(&self, x: &Matrix, y: &Matrix) -> (f32, f32) {
        let o = self.forward(x);
        (self.loss.loss(&o, y), accuracy(&o, y))
    }

    /// One Mem-AOP-GD train step (Algorithm 1 applied per layer).
    ///
    /// `state.memories[i]` must match layer i's batch/input/output dims.
    /// The RNG drives the stochastic selection policies.
    /// Serial (`threads = 1`) case of [`Mlp::train_step_aop_exec`].
    pub fn train_step_aop(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        eta: f32,
        state: &mut MlpAopState,
        rng: &mut Rng,
    ) -> MlpStepInfo {
        self.train_step_aop_exec(x, y, eta, state, rng, &Executor::serial())
    }

    /// Data-parallel Mem-AOP-GD step: forward rows, per-layer memory
    /// folding/scores/bias sums, the per-layer partial outer products and
    /// the backward chain (eq. (2a)) all run row-sharded on the
    /// executor's fixed grid; per-layer `out_K` selection stays on the
    /// calling thread (global scores, sequential RNG) so decisions are
    /// identical at every thread count, and all reductions combine in
    /// fixed shard order — curves and weights are bit-identical for any
    /// `threads`.
    pub fn train_step_aop_exec(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        eta: f32,
        state: &mut MlpAopState,
        rng: &mut Rng,
        exec: &Executor,
    ) -> MlpStepInfo {
        let n = self.layers.len();
        assert_eq!(state.memories.len(), n);
        let m = x.rows();
        let plan = exec.plan(m);
        let se = eta.sqrt();

        // Forward trace, row-sharded per layer (activations are
        // row-local; relu is applied serially — elementwise, identical
        // at any thread count).
        let mut acts: Vec<Matrix> = Vec::with_capacity(n + 1);
        let mut zs: Vec<Matrix> = Vec::with_capacity(n);
        acts.push(x.clone());
        for (li, layer) in self.layers.iter().enumerate() {
            let p = layer.fan_out();
            let mut z = Matrix::zeros(m, p);
            {
                let prev = &acts[li];
                let zb = shard::RowBlocks::of(&mut z, &plan);
                exec.run_each(&plan, |i, rows| {
                    let mut blk = zb.lock(i);
                    shard::forward_rows(prev, &layer.w, &layer.b, rows, &mut blk);
                });
            }
            let h = if li + 1 < n { relu(&z) } else { z.clone() };
            zs.push(z);
            acts.push(h);
        }

        // Head loss + output gradient, row-sharded.
        let out = &acts[n];
        let p_out = out.cols();
        let mut g = Matrix::zeros(m, p_out);
        let loss_parts: Vec<f32> = {
            let gb = shard::RowBlocks::of(&mut g, &plan);
            exec.map(&plan, |i, rows| {
                let ob = shard::rows_of(out, rows.clone());
                let lp = self.loss.partial_loss(ob, y, rows.clone());
                let mut blk = gb.lock(i);
                self.loss.grad_rows(ob, y, rows, m, &mut blk);
                lp
            })
        };
        let loss = self
            .loss
            .finish_loss(reduce::sum_f32(loss_parts), m, p_out);
        let acc = accuracy(out, y);

        let mut k_eff = 0usize;
        // Backward: compute each layer's update from the *pre-update*
        // weights, deferring weight writes until the chain is done.
        let mut new_weights: Vec<(Matrix, Vec<f32>)> = Vec::with_capacity(n);
        for i in (0..n).rev() {
            let xin = &acts[i];
            let mem = &mut state.memories[i];
            let (nf, pf) = (xin.cols(), g.cols());
            let mut xhat = Matrix::zeros(m, nf);
            let mut ghat = Matrix::zeros(m, pf);
            let mut scores = vec![0.0f32; m];
            let db_parts: Vec<Vec<f32>> = {
                let xh_blocks = shard::RowBlocks::of(&mut xhat, &plan);
                let gh_blocks = shard::RowBlocks::of(&mut ghat, &plan);
                let sc_blocks = shard::RowBlocks::of_slice(&mut scores, 1, &plan);
                exec.map(&plan, |si, rows| {
                    let mut xh = xh_blocks.lock(si);
                    shard::fold_rows(xin, &mem.mem_x, se, rows.clone(), &mut xh);
                    let mut gh = gh_blocks.lock(si);
                    shard::fold_rows(&g, &mem.mem_g, se, rows.clone(), &mut gh);
                    let mut sc = sc_blocks.lock(si);
                    shard::score_rows(&xh, &gh, nf, pf, &mut sc);
                    shard::col_sums_rows(shard::rows_of(&g, rows), pf)
                })
            };
            let sel = policy::select(
                state.policy,
                &scores,
                state.k.min(scores.len()),
                mem.enabled,
                rng,
            );
            k_eff += sel.k_effective();
            let pairs = sel.compact_pairs();
            let wstar_parts: Vec<Option<Matrix>> = exec.map(&plan, |_, rows| {
                let local: Vec<(usize, f32)> = pairs
                    .iter()
                    .copied()
                    .filter(|(r, _)| rows.contains(r))
                    .collect();
                if local.is_empty() {
                    None
                } else {
                    Some(ops::masked_outer_compact(&xhat, &ghat, &local))
                }
            });
            let wstar = reduce::sum_matrices(nf, pf, wstar_parts);
            let layer = &self.layers[i];
            let w_new = layer.w.sub(&wstar);
            let db = reduce::sum_vecs(pf, db_parts.iter().map(|d| d.as_slice()));
            let b_new: Vec<f32> = layer
                .b
                .iter()
                .zip(db.iter())
                .map(|(b, d)| b - eta * d)
                .collect();
            if mem.enabled {
                let mx_blocks = shard::RowBlocks::of(&mut mem.mem_x, &plan);
                let mg_blocks = shard::RowBlocks::of(&mut mem.mem_g, &plan);
                exec.run_each(&plan, |si, rows| {
                    let mut mx = mx_blocks.lock(si);
                    shard::keep_rows(&xhat, &sel.keep, rows.clone(), &mut mx);
                    let mut mg = mg_blocks.lock(si);
                    shard::keep_rows(&ghat, &sel.keep, rows, &mut mg);
                });
            }
            new_weights.push((w_new, b_new));

            if i > 0 {
                // eq. (2a): G_i = G_{i+1} W_i^T ⊙ relu'(z_{i-1}) —
                // row-local, so sharding is bitwise-free
                let wt = layer.w.transpose();
                let z_prev = &zs[i - 1];
                let mut g_next = Matrix::zeros(m, nf);
                {
                    let gn_blocks = shard::RowBlocks::of(&mut g_next, &plan);
                    exec.run_each(&plan, |si, rows| {
                        let mut blk = gn_blocks.lock(si);
                        ops::matmul_rows(&g, &wt, rows.clone(), &mut blk);
                        let zb = shard::rows_of(z_prev, rows);
                        for (v, &z) in blk.iter_mut().zip(zb.iter()) {
                            *v *= (z > 0.0) as u32 as f32;
                        }
                    });
                }
                g = g_next;
            }
        }
        for (i, (w, b)) in new_weights.into_iter().enumerate() {
            let layer_idx = n - 1 - i;
            self.layers[layer_idx].w = w;
            self.layers[layer_idx].b = b;
        }
        MlpStepInfo {
            loss,
            acc,
            k_effective: k_eff,
        }
    }

    /// Exact SGD step (baseline comparator).
    pub fn train_step_sgd(&mut self, x: &Matrix, y: &Matrix, eta: f32) -> MlpStepInfo {
        let mut memories: Vec<MemoryState> = self
            .layers
            .iter()
            .map(|l| MemoryState::new(x.rows(), l.fan_in(), l.fan_out(), false))
            .collect();
        let mut state = MlpAopState {
            memories: std::mem::take(&mut memories),
            policy: Policy::Exact,
            k: x.rows(),
        };
        let mut rng = Rng::new(0); // unused by Exact
        self.train_step_aop(x, y, eta, &mut state, &mut rng)
    }
}

/// Build per-layer memories for an MLP/batch pair.
pub fn mlp_memories(mlp: &Mlp, batch: usize, enabled: bool) -> Vec<MemoryState> {
    mlp.layers
        .iter()
        .map(|l| MemoryState::new(batch, l.fan_in(), l.fan_out(), enabled))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_data(rng: &mut Rng, b: usize, nin: usize, nout: usize) -> (Matrix, Matrix) {
        let x = Matrix::from_fn(b, nin, |_, _| rng.normal());
        let y = Matrix::from_fn(b, nout, |r, c| ((r % nout) == c) as u32 as f32);
        (x, y)
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(0);
        let mlp = Mlp::new(&mut rng, &[8, 16, 4], LossKind::SoftmaxCrossEntropy);
        let (x, _) = toy_data(&mut rng, 5, 8, 4);
        assert_eq!(mlp.forward(&x).shape(), (5, 4));
        let (acts, zs) = mlp.forward_trace(&x);
        assert_eq!(acts.len(), 3);
        assert_eq!(zs.len(), 2);
        assert_eq!(acts[1].shape(), (5, 16));
    }

    #[test]
    fn num_params() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::new(&mut rng, &[10, 20, 5], LossKind::SoftmaxCrossEntropy);
        assert_eq!(mlp.num_params(), 10 * 20 + 20 + 20 * 5 + 5);
        assert_eq!(mlp.widths(), vec![10, 20, 5]);
    }

    #[test]
    fn sgd_step_reduces_loss_on_fixed_batch() {
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 12, 6, 3);
        let before = mlp.evaluate(&x, &y).0;
        for _ in 0..30 {
            mlp.train_step_sgd(&x, &y, 0.1);
        }
        let after = mlp.evaluate(&x, &y).0;
        assert!(after < before * 0.7, "before={before} after={after}");
    }

    #[test]
    fn aop_topk_step_reduces_loss() {
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&mut rng, &[6, 12, 3], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 16, 6, 3);
        let mut state = MlpAopState {
            memories: mlp_memories(&mlp, 16, true),
            policy: Policy::TopK,
            k: 4,
        };
        let before = mlp.evaluate(&x, &y).0;
        for _ in 0..60 {
            mlp.train_step_aop(&x, &y, 0.1, &mut state, &mut rng);
        }
        let after = mlp.evaluate(&x, &y).0;
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    #[test]
    fn exact_policy_is_sgd() {
        // Exact AOP (all rows, no memory) must equal the plain SGD step.
        let mut rng = Rng::new(4);
        let mlp0 = Mlp::new(&mut rng, &[5, 8, 2], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 10, 5, 2);

        let mut a = mlp0.clone();
        a.train_step_sgd(&x, &y, 0.05);

        let mut b = mlp0.clone();
        let mut state = MlpAopState {
            memories: mlp_memories(&b, 10, false),
            policy: Policy::Exact,
            k: 10,
        };
        let mut r2 = Rng::new(99);
        b.train_step_aop(&x, &y, 0.05, &mut state, &mut r2);

        for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
            assert!(la.w.max_abs_diff(&lb.w) < 1e-6);
        }
    }

    #[test]
    fn k_effective_counts_selected_products() {
        let mut rng = Rng::new(5);
        let mut mlp = Mlp::new(&mut rng, &[4, 6, 2], LossKind::SoftmaxCrossEntropy);
        let (x, y) = toy_data(&mut rng, 8, 4, 2);
        let mut state = MlpAopState {
            memories: mlp_memories(&mlp, 8, true),
            policy: Policy::TopK,
            k: 3,
        };
        let info = mlp.train_step_aop(&x, &y, 0.05, &mut state, &mut rng);
        assert_eq!(info.k_effective, 3 * 2); // k per layer × 2 layers
    }

    #[test]
    fn single_layer_mse_matches_manual_gradient() {
        // one linear layer + MSE: W* = X^T G exactly
        let mut rng = Rng::new(6);
        let mut mlp = Mlp::new(&mut rng, &[3, 2], LossKind::Mse);
        let x = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(4, 2, |_, _| rng.normal());
        let w0 = mlp.layers[0].w.clone();
        let o = mlp.forward(&x);
        let (_, g) = LossKind::Mse.loss_and_grad(&o, &y);
        let eta = 0.1f32;
        mlp.train_step_sgd(&x, &y, eta);
        let expect = w0.sub(&ops::matmul_tn(&x, &g).scale(eta));
        assert!(mlp.layers[0].w.max_abs_diff(&expect) < 1e-5);
    }
}
