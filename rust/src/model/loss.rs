//! Losses and their output gradients (`G_L` of Sec. II-A).
//!
//! The gradient definitions match `python/compile/model.py` exactly:
//!
//! * MSE:  `L = mean((O - Y)^2)`, `G = 2 (O - Y) / (B · P)`;
//! * CCE:  `L = -mean(Σ_p Y log softmax(O))`, `G = (softmax(O) - Y) / B`.

use crate::model::activations::{log_softmax_rows, softmax_rows};
use crate::tensor::Matrix;

/// Loss selector (Tab. I: MSE for energy, CCE for mnist).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Mean squared error over all entries.
    Mse,
    /// Categorical cross-entropy over softmax rows (one-hot targets).
    SoftmaxCrossEntropy,
}

impl LossKind {
    pub fn parse(s: &str) -> Option<LossKind> {
        Some(match s {
            "mse" => LossKind::Mse,
            "cce" | "softmax_cce" => LossKind::SoftmaxCrossEntropy,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Mse => "mse",
            LossKind::SoftmaxCrossEntropy => "cce",
        }
    }

    /// Loss value and gradient w.r.t. the pre-activation output `o`.
    pub fn loss_and_grad(&self, o: &Matrix, y: &Matrix) -> (f32, Matrix) {
        assert_eq!(o.shape(), y.shape());
        match self {
            LossKind::Mse => {
                let n = (o.rows() * o.cols()) as f32;
                let diff = o.sub(y);
                let loss = diff.data().iter().map(|v| v * v).sum::<f32>() / n;
                (loss, diff.scale(2.0 / n))
            }
            LossKind::SoftmaxCrossEntropy => {
                let b = o.rows() as f32;
                let logp = log_softmax_rows(o);
                let loss = -y
                    .data()
                    .iter()
                    .zip(logp.data().iter())
                    .map(|(yv, lv)| yv * lv)
                    .sum::<f32>()
                    / b;
                let mut g = softmax_rows(o);
                g.axpy(-1.0, y);
                (loss, g.scale(1.0 / b))
            }
        }
    }

    /// Loss value only (validation path).
    pub fn loss(&self, o: &Matrix, y: &Matrix) -> f32 {
        self.loss_and_grad(o, y).0
    }
}

/// Argmax-agreement accuracy (classification diagnostics).
pub fn accuracy(o: &Matrix, y: &Matrix) -> f32 {
    assert_eq!(o.shape(), y.shape());
    let mut correct = 0usize;
    for r in 0..o.rows() {
        let am = |row: &[f32]| -> usize {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(0)
        };
        if am(o.row(r)) == am(y.row(r)) {
            correct += 1;
        }
    }
    correct as f32 / o.rows() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn mse_value_and_grad() {
        let o = Matrix::from_vec(2, 1, vec![1.0, 3.0]);
        let y = Matrix::from_vec(2, 1, vec![0.0, 1.0]);
        let (loss, g) = LossKind::Mse.loss_and_grad(&o, &y);
        assert!((loss - 2.5).abs() < 1e-6); // (1 + 4)/2
        assert!((g[(0, 0)] - 1.0).abs() < 1e-6); // 2*1/2
        assert!((g[(1, 0)] - 2.0).abs() < 1e-6); // 2*2/2
    }

    #[test]
    fn mse_grad_is_numeric_derivative() {
        let mut rng = Rng::new(0);
        let o = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let (_, g) = LossKind::Mse.loss_and_grad(&o, &y);
        let eps = 1e-3f32;
        for (r, c) in [(0, 0), (2, 1), (3, 2)] {
            let mut op = o.clone();
            op[(r, c)] += eps;
            let mut om = o.clone();
            om[(r, c)] -= eps;
            let num = (LossKind::Mse.loss(&op, &y) - LossKind::Mse.loss(&om, &y)) / (2.0 * eps);
            assert!((num - g[(r, c)]).abs() < 1e-3, "({r},{c})");
        }
    }

    #[test]
    fn cce_grad_is_numeric_derivative() {
        let mut rng = Rng::new(1);
        let o = Matrix::from_fn(5, 4, |_, _| rng.normal());
        let y = Matrix::from_fn(5, 4, |r, c| ((r + 1) % 4 == c) as u32 as f32);
        let kind = LossKind::SoftmaxCrossEntropy;
        let (_, g) = kind.loss_and_grad(&o, &y);
        let eps = 1e-2f32;
        for (r, c) in [(0, 0), (1, 3), (4, 2)] {
            let mut op = o.clone();
            op[(r, c)] += eps;
            let mut om = o.clone();
            om[(r, c)] -= eps;
            let num = (kind.loss(&op, &y) - kind.loss(&om, &y)) / (2.0 * eps);
            assert!((num - g[(r, c)]).abs() < 1e-3, "({r},{c}): {num} vs {}", g[(r, c)]);
        }
    }

    #[test]
    fn cce_perfect_prediction_low_loss() {
        // logits strongly favoring the true class
        let y = Matrix::from_fn(3, 3, |r, c| (r == c) as u32 as f32);
        let o = y.scale(20.0);
        let loss = LossKind::SoftmaxCrossEntropy.loss(&o, &y);
        assert!(loss < 1e-3, "loss={loss}");
    }

    #[test]
    fn accuracy_counts_argmax_matches() {
        let o = Matrix::from_vec(3, 2, vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        let y = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0]);
        assert!((accuracy(&o, &y) - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn parse_names() {
        assert_eq!(LossKind::parse("mse"), Some(LossKind::Mse));
        assert_eq!(LossKind::parse("cce"), Some(LossKind::SoftmaxCrossEntropy));
        assert_eq!(LossKind::parse("hinge"), None);
    }
}
