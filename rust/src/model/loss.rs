//! Losses and their output gradients (`G_L` of Sec. II-A).
//!
//! The gradient definitions match `python/compile/model.py` exactly:
//!
//! * MSE:  `L = mean((O - Y)^2)`, `G = 2 (O - Y) / (B · P)`;
//! * CCE:  `L = -mean(Σ_p Y log softmax(O))`, `G = (softmax(O) - Y) / B`.

use crate::model::activations::{log_softmax_rows, softmax_rows};
use crate::tensor::Matrix;

/// Loss selector (Tab. I: MSE for energy, CCE for mnist).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Mean squared error over all entries.
    Mse,
    /// Categorical cross-entropy over softmax rows (one-hot targets).
    SoftmaxCrossEntropy,
}

impl LossKind {
    pub fn parse(s: &str) -> Option<LossKind> {
        Some(match s {
            "mse" => LossKind::Mse,
            "cce" | "softmax_cce" => LossKind::SoftmaxCrossEntropy,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Mse => "mse",
            LossKind::SoftmaxCrossEntropy => "cce",
        }
    }

    /// Loss value and gradient w.r.t. the pre-activation output `o`.
    pub fn loss_and_grad(&self, o: &Matrix, y: &Matrix) -> (f32, Matrix) {
        assert_eq!(o.shape(), y.shape());
        match self {
            LossKind::Mse => {
                let n = (o.rows() * o.cols()) as f32;
                let diff = o.sub(y);
                let loss = diff.data().iter().map(|v| v * v).sum::<f32>() / n;
                (loss, diff.scale(2.0 / n))
            }
            LossKind::SoftmaxCrossEntropy => {
                let b = o.rows() as f32;
                let logp = log_softmax_rows(o);
                let loss = -y
                    .data()
                    .iter()
                    .zip(logp.data().iter())
                    .map(|(yv, lv)| yv * lv)
                    .sum::<f32>()
                    / b;
                let mut g = softmax_rows(o);
                g.axpy(-1.0, y);
                (loss, g.scale(1.0 / b))
            }
        }
    }

    /// Loss value only (validation path).
    pub fn loss(&self, o: &Matrix, y: &Matrix) -> f32 {
        self.loss_and_grad(o, y).0
    }

    // --- row-range API (the `exec` subsystem's shard kernels) ---------
    //
    // A shard computes `partial_loss` over its rows; the coordinator sums
    // the partials in fixed shard order and normalizes with
    // `finish_loss`. Gradients are row-local, so `grad_rows` is bitwise
    // the restriction of `loss_and_grad`'s gradient to the range.

    /// Unnormalized loss contribution of `rows`, whose forward outputs
    /// are the shard-local block `o_rows` (`rows.len() × y.cols()`,
    /// row-major). MSE: Σ (o−y)²; CCE: Σ y·log-softmax(o) (note: *not*
    /// yet negated — `finish_loss` applies sign and normalizer).
    pub fn partial_loss(&self, o_rows: &[f32], y: &Matrix, rows: std::ops::Range<usize>) -> f32 {
        let p = y.cols();
        assert_eq!(o_rows.len(), rows.len() * p, "output block size");
        match self {
            LossKind::Mse => {
                let mut acc = 0.0f32;
                for (local, r) in rows.enumerate() {
                    let orow = &o_rows[local * p..(local + 1) * p];
                    for (ov, &yv) in orow.iter().zip(y.row(r).iter()) {
                        let d = ov - yv;
                        acc += d * d;
                    }
                }
                acc
            }
            LossKind::SoftmaxCrossEntropy => {
                let mut acc = 0.0f32;
                for (local, r) in rows.enumerate() {
                    let orow = &o_rows[local * p..(local + 1) * p];
                    // stable log-softmax, same math as `log_softmax_rows`
                    let mx = orow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let lse = orow.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
                    for (ov, &yv) in orow.iter().zip(y.row(r).iter()) {
                        acc += yv * (ov - mx - lse);
                    }
                }
                acc
            }
        }
    }

    /// Normalize a fixed-order total of [`LossKind::partial_loss`] values
    /// for a batch of `batch_rows × cols` outputs.
    pub fn finish_loss(&self, total: f32, batch_rows: usize, cols: usize) -> f32 {
        match self {
            LossKind::Mse => total / (batch_rows * cols) as f32,
            LossKind::SoftmaxCrossEntropy => -total / batch_rows as f32,
        }
    }

    /// Output-gradient rows for `rows` into `g_rows` (same block shape as
    /// `o_rows`). `batch_rows` is the full mini-batch size — the gradient
    /// normalizer depends on it, not on the shard size.
    pub fn grad_rows(
        &self,
        o_rows: &[f32],
        y: &Matrix,
        rows: std::ops::Range<usize>,
        batch_rows: usize,
        g_rows: &mut [f32],
    ) {
        let p = y.cols();
        assert_eq!(o_rows.len(), rows.len() * p, "output block size");
        assert_eq!(g_rows.len(), o_rows.len(), "gradient block size");
        match self {
            LossKind::Mse => {
                let c = 2.0 / (batch_rows * p) as f32;
                for (local, r) in rows.enumerate() {
                    let orow = &o_rows[local * p..(local + 1) * p];
                    let grow = &mut g_rows[local * p..(local + 1) * p];
                    for ((gv, ov), &yv) in grow.iter_mut().zip(orow.iter()).zip(y.row(r).iter()) {
                        *gv = (ov - yv) * c;
                    }
                }
            }
            LossKind::SoftmaxCrossEntropy => {
                let c = 1.0 / batch_rows as f32;
                for (local, r) in rows.enumerate() {
                    let orow = &o_rows[local * p..(local + 1) * p];
                    let grow = &mut g_rows[local * p..(local + 1) * p];
                    // stable softmax, same math as `softmax_rows`
                    let mx = orow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0f32;
                    for (gv, ov) in grow.iter_mut().zip(orow.iter()) {
                        *gv = (ov - mx).exp();
                        sum += *gv;
                    }
                    for (gv, &yv) in grow.iter_mut().zip(y.row(r).iter()) {
                        *gv = (*gv / sum - yv) * c;
                    }
                }
            }
        }
    }
}

/// Index of a row's largest entry (first wins on ties/NaN) — the one
/// argmax both [`accuracy`] and [`correct_rows`] share, so their
/// tie-breaking can never drift apart.
fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Argmax-agreement count over a row range (shard partial of
/// [`accuracy`]; integer, so exact under any reduction order).
pub fn correct_rows(o_rows: &[f32], y: &Matrix, rows: std::ops::Range<usize>) -> usize {
    let p = y.cols();
    assert_eq!(o_rows.len(), rows.len() * p, "output block size");
    let mut correct = 0usize;
    for (local, r) in rows.enumerate() {
        if argmax(&o_rows[local * p..(local + 1) * p]) == argmax(y.row(r)) {
            correct += 1;
        }
    }
    correct
}

/// Argmax-agreement accuracy (classification diagnostics). Delegates to
/// [`correct_rows`] over the whole batch — one argmax definition.
pub fn accuracy(o: &Matrix, y: &Matrix) -> f32 {
    assert_eq!(o.shape(), y.shape());
    correct_rows(o.data(), y, 0..o.rows()) as f32 / o.rows() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn mse_value_and_grad() {
        let o = Matrix::from_vec(2, 1, vec![1.0, 3.0]);
        let y = Matrix::from_vec(2, 1, vec![0.0, 1.0]);
        let (loss, g) = LossKind::Mse.loss_and_grad(&o, &y);
        assert!((loss - 2.5).abs() < 1e-6); // (1 + 4)/2
        assert!((g[(0, 0)] - 1.0).abs() < 1e-6); // 2*1/2
        assert!((g[(1, 0)] - 2.0).abs() < 1e-6); // 2*2/2
    }

    #[test]
    fn mse_grad_is_numeric_derivative() {
        let mut rng = Rng::new(0);
        let o = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let y = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let (_, g) = LossKind::Mse.loss_and_grad(&o, &y);
        let eps = 1e-3f32;
        for (r, c) in [(0, 0), (2, 1), (3, 2)] {
            let mut op = o.clone();
            op[(r, c)] += eps;
            let mut om = o.clone();
            om[(r, c)] -= eps;
            let num = (LossKind::Mse.loss(&op, &y) - LossKind::Mse.loss(&om, &y)) / (2.0 * eps);
            assert!((num - g[(r, c)]).abs() < 1e-3, "({r},{c})");
        }
    }

    #[test]
    fn cce_grad_is_numeric_derivative() {
        let mut rng = Rng::new(1);
        let o = Matrix::from_fn(5, 4, |_, _| rng.normal());
        let y = Matrix::from_fn(5, 4, |r, c| ((r + 1) % 4 == c) as u32 as f32);
        let kind = LossKind::SoftmaxCrossEntropy;
        let (_, g) = kind.loss_and_grad(&o, &y);
        let eps = 1e-2f32;
        for (r, c) in [(0, 0), (1, 3), (4, 2)] {
            let mut op = o.clone();
            op[(r, c)] += eps;
            let mut om = o.clone();
            om[(r, c)] -= eps;
            let num = (kind.loss(&op, &y) - kind.loss(&om, &y)) / (2.0 * eps);
            assert!((num - g[(r, c)]).abs() < 1e-3, "({r},{c}): {num} vs {}", g[(r, c)]);
        }
    }

    #[test]
    fn cce_perfect_prediction_low_loss() {
        // logits strongly favoring the true class
        let y = Matrix::from_fn(3, 3, |r, c| (r == c) as u32 as f32);
        let o = y.scale(20.0);
        let loss = LossKind::SoftmaxCrossEntropy.loss(&o, &y);
        assert!(loss < 1e-3, "loss={loss}");
    }

    #[test]
    fn accuracy_counts_argmax_matches() {
        let o = Matrix::from_vec(3, 2, vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        let y = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0]);
        assert!((accuracy(&o, &y) - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn row_range_api_matches_whole_batch() {
        let mut rng = Rng::new(9);
        for kind in [LossKind::Mse, LossKind::SoftmaxCrossEntropy] {
            let (m, p) = (13, 4);
            let o = Matrix::from_fn(m, p, |_, _| rng.normal());
            let y = match kind {
                LossKind::Mse => Matrix::from_fn(m, p, |_, _| rng.normal()),
                LossKind::SoftmaxCrossEntropy => {
                    Matrix::from_fn(m, p, |r, c| ((r % p) == c) as u32 as f32)
                }
            };
            let (loss, g) = kind.loss_and_grad(&o, &y);

            // single full-range shard: loss and gradient match serial
            let full = kind.partial_loss(o.data(), &y, 0..m);
            assert!((kind.finish_loss(full, m, p) - loss).abs() < 1e-6, "{kind:?}");
            let mut g_full = vec![0.0f32; m * p];
            kind.grad_rows(o.data(), &y, 0..m, m, &mut g_full);
            assert_eq!(&g_full[..], g.data(), "{kind:?} grad bitwise");

            // split shards: gradients bitwise, loss within grouping tol
            let mut total = 0.0f32;
            for lo in (0..m).step_by(5) {
                let hi = (lo + 5).min(m);
                let ob = &o.data()[lo * p..hi * p];
                total += kind.partial_loss(ob, &y, lo..hi);
                let mut gb = vec![0.0f32; (hi - lo) * p];
                kind.grad_rows(ob, &y, lo..hi, m, &mut gb);
                assert_eq!(&gb[..], &g.data()[lo * p..hi * p], "{kind:?} rows {lo}..{hi}");
            }
            assert!(
                (kind.finish_loss(total, m, p) - loss).abs() < 1e-5,
                "{kind:?} sharded loss"
            );
        }
    }

    #[test]
    fn correct_rows_partials_sum_to_accuracy() {
        let o = Matrix::from_vec(3, 2, vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        let y = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0]);
        let total = correct_rows(&o.data()[0..2], &y, 0..1)
            + correct_rows(&o.data()[2..6], &y, 1..3);
        assert_eq!(total, 2);
        assert!((total as f32 / 3.0 - accuracy(&o, &y)).abs() < 1e-6);
    }

    #[test]
    fn parse_names() {
        assert_eq!(LossKind::parse("mse"), Some(LossKind::Mse));
        assert_eq!(LossKind::parse("cce"), Some(LossKind::SoftmaxCrossEntropy));
        assert_eq!(LossKind::parse("hinge"), None);
    }
}
