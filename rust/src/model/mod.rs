//! Native model substrate: layers, activations, losses, and the MLP
//! definition shared by the native trainer and the e2e example.
//!
//! Matches the Layer-2 JAX graphs operation-for-operation so the native
//! and HLO training paths are interchangeable oracles of each other.

pub mod activations;
pub mod loss;
pub mod mlp;

pub use loss::LossKind;
pub use mlp::{DenseLayer, Mlp};
