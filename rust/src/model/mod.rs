//! Native model substrate: activations, losses, and the MLP alias
//! surface over the layer-graph core (`crate::train`).
//!
//! Matches the Layer-2 JAX graphs operation-for-operation so the native
//! and HLO training paths are interchangeable oracles of each other.

pub mod activations;
pub mod loss;
pub mod mlp;

pub use activations::Activation;
pub use loss::LossKind;
pub use mlp::{DenseLayer, Mlp};
