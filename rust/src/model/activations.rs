//! Activations (numerically-stable, matching `jax.nn` semantics).
//!
//! [`Activation`] is the pluggable per-layer nonlinearity of the
//! layer-graph training core (`crate::train`): forward is applied
//! elementwise on shard-local row blocks, and the backward chain's
//! derivative is computed *from the activation output* `h` — which for
//! every supported activation is cheaper than (and for relu bitwise
//! identical to) evaluating the derivative from the pre-activation `z`,
//! so the forward trace never has to retain `z` at all.

use crate::tensor::Matrix;

/// Pluggable elementwise layer activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    /// `h = z` — the linear head (and the paper's single-layer model).
    Identity,
    /// `h = max(z, 0)` — the MLP default.
    Relu,
    /// `h = tanh(z)`.
    Tanh,
    /// `h = 1 / (1 + e^{-z})`.
    Sigmoid,
}

impl Activation {
    /// Parse config / CLI names (case-insensitive, trimmed).
    pub fn parse(s: &str) -> Option<Activation> {
        Some(match s.trim().to_ascii_lowercase().as_str() {
            "identity" | "linear" | "none" => Activation::Identity,
            "relu" => Activation::Relu,
            "tanh" => Activation::Tanh,
            "sigmoid" => Activation::Sigmoid,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::Identity => "identity",
            Activation::Relu => "relu",
            Activation::Tanh => "tanh",
            Activation::Sigmoid => "sigmoid",
        }
    }

    /// Every activation, in help/metrics order.
    pub fn all() -> [Activation; 4] {
        [
            Activation::Identity,
            Activation::Relu,
            Activation::Tanh,
            Activation::Sigmoid,
        ]
    }

    /// Scalar forward `h = f(z)`.
    pub fn f(&self, z: f32) -> f32 {
        match self {
            Activation::Identity => z,
            Activation::Relu => z.max(0.0),
            Activation::Tanh => z.tanh(),
            Activation::Sigmoid => 1.0 / (1.0 + (-z).exp()),
        }
    }

    /// Derivative `f'(z)` expressed through the *output* `h = f(z)`:
    ///
    /// * identity: 1;
    /// * relu: `h > 0` — bitwise the same 0/1 mask as `z > 0` since
    ///   `h = max(z, 0)` is positive exactly when `z` is;
    /// * tanh: `1 − h²`;
    /// * sigmoid: `h (1 − h)`.
    pub fn grad_from_output(&self, h: f32) -> f32 {
        match self {
            Activation::Identity => 1.0,
            Activation::Relu => (h > 0.0) as u32 as f32,
            Activation::Tanh => 1.0 - h * h,
            Activation::Sigmoid => h * (1.0 - h),
        }
    }

    /// Apply in place to a shard-local row block (no-op for identity, so
    /// linear layers pay nothing).
    pub fn apply_block(&self, block: &mut [f32]) {
        if *self == Activation::Identity {
            return;
        }
        for v in block.iter_mut() {
            *v = self.f(*v);
        }
    }

    /// Apply to an owned matrix. Identity moves the matrix through
    /// untouched — the final pre-activation is never cloned.
    pub fn apply_owned(&self, mut z: Matrix) -> Matrix {
        self.apply_block(z.data_mut());
        z
    }
}

/// Elementwise relu.
pub fn relu(m: &Matrix) -> Matrix {
    m.map(|v| v.max(0.0))
}

/// relu'(z) as a 0/1 matrix (for the backward chain, eq. (2a)).
pub fn relu_grad_mask(z: &Matrix) -> Matrix {
    z.map(|v| (v > 0.0) as u32 as f32)
}

/// Row-wise softmax with max-subtraction (stable).
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// Row-wise log-softmax (stable: `z - max - log Σ exp(z - max)`).
pub fn log_softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
        for v in row.iter_mut() {
            *v = *v - mx - lse;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn activation_parse_roundtrip() {
        for a in Activation::all() {
            assert_eq!(Activation::parse(a.name()), Some(a));
        }
        assert_eq!(Activation::parse(" ReLU "), Some(Activation::Relu));
        assert_eq!(Activation::parse("linear"), Some(Activation::Identity));
        assert_eq!(Activation::parse("gelu"), None);
    }

    #[test]
    fn grad_from_output_matches_numeric_derivative() {
        for a in Activation::all() {
            for &z in &[-2.0f32, -0.5, 0.3, 1.7] {
                let h = a.f(z);
                let eps = 1e-3f32;
                let num = (a.f(z + eps) - a.f(z - eps)) / (2.0 * eps);
                let ana = a.grad_from_output(h);
                assert!((num - ana).abs() < 1e-2, "{a:?} at z={z}: {num} vs {ana}");
            }
        }
    }

    #[test]
    fn relu_grad_from_output_equals_z_mask() {
        // the bitwise claim the backward chain relies on
        for &z in &[-3.0f32, -0.0, 0.0, 1e-20, 4.0] {
            let h = Activation::Relu.f(z);
            assert_eq!(
                Activation::Relu.grad_from_output(h).to_bits(),
                ((z > 0.0) as u32 as f32).to_bits(),
                "z={z}"
            );
        }
    }

    #[test]
    fn apply_owned_identity_is_noop() {
        let m = Matrix::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        let data_before = m.data().to_vec();
        let out = Activation::Identity.apply_owned(m);
        assert_eq!(out.data(), &data_before[..]);
        let t = Activation::Tanh.apply_owned(out);
        assert!((t[(0, 2)] - 2.0f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn relu_clamps() {
        let m = Matrix::from_vec(1, 4, vec![-2.0, -0.0, 0.5, 3.0]);
        assert_eq!(relu(&m).data(), &[0.0, 0.0, 0.5, 3.0]);
        assert_eq!(relu_grad_mask(&m).data(), &[0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(0);
        let m = Matrix::from_fn(6, 9, |_, _| rng.normal() * 3.0);
        let s = softmax_rows(&m);
        for r in 0..6 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.row(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let m = Matrix::from_vec(1, 3, vec![1000.0, 1001.0, 999.0]);
        let s = softmax_rows(&m);
        assert!(s.is_finite());
        assert!((s.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(s[(0, 1)] > s[(0, 0)] && s[(0, 0)] > s[(0, 2)]);
    }

    #[test]
    fn log_softmax_is_log_of_softmax() {
        let mut rng = Rng::new(1);
        let m = Matrix::from_fn(4, 5, |_, _| rng.normal());
        let a = log_softmax_rows(&m);
        let b = softmax_rows(&m).map(|v| v.ln());
        assert!(a.max_abs_diff(&b) < 1e-5);
    }
}
