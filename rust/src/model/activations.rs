//! Activations (numerically-stable, matching `jax.nn` semantics).

use crate::tensor::Matrix;

/// Elementwise relu.
pub fn relu(m: &Matrix) -> Matrix {
    m.map(|v| v.max(0.0))
}

/// relu'(z) as a 0/1 matrix (for the backward chain, eq. (2a)).
pub fn relu_grad_mask(z: &Matrix) -> Matrix {
    z.map(|v| (v > 0.0) as u32 as f32)
}

/// Row-wise softmax with max-subtraction (stable).
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// Row-wise log-softmax (stable: `z - max - log Σ exp(z - max)`).
pub fn log_softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
        for v in row.iter_mut() {
            *v = *v - mx - lse;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn relu_clamps() {
        let m = Matrix::from_vec(1, 4, vec![-2.0, -0.0, 0.5, 3.0]);
        assert_eq!(relu(&m).data(), &[0.0, 0.0, 0.5, 3.0]);
        assert_eq!(relu_grad_mask(&m).data(), &[0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(0);
        let m = Matrix::from_fn(6, 9, |_, _| rng.normal() * 3.0);
        let s = softmax_rows(&m);
        for r in 0..6 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.row(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let m = Matrix::from_vec(1, 3, vec![1000.0, 1001.0, 999.0]);
        let s = softmax_rows(&m);
        assert!(s.is_finite());
        assert!((s.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(s[(0, 1)] > s[(0, 0)] && s[(0, 0)] > s[(0, 2)]);
    }

    #[test]
    fn log_softmax_is_log_of_softmax() {
        let mut rng = Rng::new(1);
        let m = Matrix::from_fn(4, 5, |_, _| rng.normal());
        let a = log_softmax_rows(&m);
        let b = softmax_rows(&m).map(|v| v.ln());
        assert!(a.max_abs_diff(&b) < 1e-5);
    }
}
