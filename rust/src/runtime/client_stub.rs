//! Native-only stand-in for the PJRT client, compiled when the `hlo`
//! cargo feature is disabled (the default in the offline environment).
//!
//! Presents the exact same typed surface as `client.rs` so trainers,
//! benches and the serve subsystem compile unchanged; every entry point
//! that would need a PJRT plugin returns a clear "backend unavailable"
//! error instead. The native backend (`--backend native`) is unaffected.

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::values::{ArgRef, ExecStats, Value};

const UNAVAILABLE: &str = "HLO/PJRT backend unavailable: this binary was built without the \
     `hlo` cargo feature. Rebuild with `cargo build --features hlo` (after vendoring the \
     real xla bindings, see vendor/xla), or rerun with `--backend native`";

/// Stub of the compiled-artifact handle. Cannot be constructed (the stub
/// [`Runtime`] never hands one out); methods exist for type-compatibility.
pub struct Executable {
    spec: ArtifactSpec,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    pub fn stats(&self) -> ExecStats {
        ExecStats::default()
    }

    pub fn run(&self, _args: &[Value]) -> Result<Vec<Value>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn run_ref(&self, _args: &[ArgRef<'_>]) -> Result<Vec<Value>> {
        bail!("{UNAVAILABLE}");
    }
}

/// Stub runtime: construction always fails with the unavailable message.
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(_artifacts_dir: &Path) -> Result<Runtime> {
        bail!("{UNAVAILABLE}");
    }

    pub fn from_default_artifacts() -> Result<Runtime> {
        Self::new(&Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the hlo feature)".to_string()
    }

    pub fn load(&self, _name: &str) -> Result<Rc<Executable>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn load_all(&self) -> Result<Vec<(String, ExecStats)>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::from_default_artifacts().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("hlo"), "{msg}");
        assert!(msg.contains("--backend native"), "{msg}");
    }
}
