//! Typed values crossing the Rust ⇄ runtime boundary.
//!
//! Shared between the real PJRT client (`hlo` feature) and the
//! native-only stub, so trainers and tests compile identically in both
//! configurations.

use anyhow::{bail, Result};

use crate::runtime::manifest::TensorSpec;
use crate::tensor::Matrix;

/// A typed value crossing the Rust ⇄ PJRT boundary.
#[derive(Debug, Clone)]
pub enum Value {
    Scalar(f32),
    Vector(Vec<f32>),
    Matrix(Matrix),
}

/// Borrowed argument for `Executable::run_ref` — lets the hot path feed
/// model state without cloning matrices into [`Value`]s first (§Perf).
#[derive(Debug, Clone, Copy)]
pub enum ArgRef<'a> {
    Scalar(f32),
    Vector(&'a [f32]),
    Matrix(&'a Matrix),
}

impl<'a> ArgRef<'a> {
    pub(crate) fn shape(&self) -> Vec<usize> {
        match self {
            ArgRef::Scalar(_) => vec![],
            ArgRef::Vector(v) => vec![v.len()],
            ArgRef::Matrix(m) => vec![m.rows(), m.cols()],
        }
    }

    pub(crate) fn data(&self) -> &[f32] {
        match self {
            ArgRef::Scalar(v) => std::slice::from_ref(v),
            ArgRef::Vector(v) => v,
            ArgRef::Matrix(m) => m.data(),
        }
    }
}

impl<'a> From<&'a Value> for ArgRef<'a> {
    fn from(v: &'a Value) -> Self {
        match v {
            Value::Scalar(s) => ArgRef::Scalar(*s),
            Value::Vector(v) => ArgRef::Vector(v),
            Value::Matrix(m) => ArgRef::Matrix(m),
        }
    }
}

impl<'a> From<&'a Matrix> for ArgRef<'a> {
    fn from(m: &'a Matrix) -> Self {
        ArgRef::Matrix(m)
    }
}

impl<'a> From<&'a [f32]> for ArgRef<'a> {
    fn from(v: &'a [f32]) -> Self {
        ArgRef::Vector(v)
    }
}

impl<'a> From<&'a Vec<f32>> for ArgRef<'a> {
    fn from(v: &'a Vec<f32>) -> Self {
        ArgRef::Vector(v)
    }
}

impl From<f32> for ArgRef<'static> {
    fn from(v: f32) -> Self {
        ArgRef::Scalar(v)
    }
}

impl Value {
    pub fn as_scalar(&self) -> Result<f32> {
        match self {
            Value::Scalar(v) => Ok(*v),
            _ => bail!("expected scalar, got {self:?}"),
        }
    }

    pub fn as_vector(&self) -> Result<&[f32]> {
        match self {
            Value::Vector(v) => Ok(v),
            _ => bail!("expected vector"),
        }
    }

    pub fn into_matrix(self) -> Result<Matrix> {
        match self {
            Value::Matrix(m) => Ok(m),
            _ => bail!("expected matrix"),
        }
    }

    pub fn into_vector(self) -> Result<Vec<f32>> {
        match self {
            Value::Vector(v) => Ok(v),
            _ => bail!("expected vector"),
        }
    }

    /// Build from a spec + flat data (output unmarshalling).
    pub(crate) fn from_flat(spec: &TensorSpec, data: Vec<f32>) -> Result<Value> {
        if data.len() != spec.num_elements() {
            bail!(
                "output '{}': got {} elements, expected {}",
                spec.name,
                data.len(),
                spec.num_elements()
            );
        }
        Ok(match spec.shape.len() {
            0 => Value::Scalar(data[0]),
            1 => Value::Vector(data),
            2 => Value::Matrix(Matrix::from_vec(spec.shape[0], spec.shape[1], data)),
            n => bail!("output '{}': rank {n} unsupported", spec.name),
        })
    }
}

impl From<f32> for Value {
    fn from(v: f32) -> Self {
        Value::Scalar(v)
    }
}

impl From<Vec<f32>> for Value {
    fn from(v: Vec<f32>) -> Self {
        Value::Vector(v)
    }
}

impl From<Matrix> for Value {
    fn from(m: Matrix) -> Self {
        Value::Matrix(m)
    }
}

impl From<&Matrix> for Value {
    fn from(m: &Matrix) -> Self {
        Value::Matrix(m.clone())
    }
}

/// Cumulative execution stats for one artifact.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ns: u64,
    pub compile_ns: u64,
}

impl ExecStats {
    pub fn mean_us(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.calls as f64 / 1e3
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argref_shape_data() {
        let v = Value::Scalar(2.0);
        let r = ArgRef::from(&v);
        assert!(r.shape().is_empty());
        assert_eq!(r.data(), &[2.0]);
        let vec_val = vec![1.0f32, 2.0];
        let r = ArgRef::from(&vec_val);
        assert_eq!(r.shape(), vec![2]);
        assert_eq!(r.data().len(), 2);
        let m = Matrix::zeros(3, 4);
        let r = ArgRef::from(&m);
        assert_eq!(r.shape(), vec![3, 4]);
        assert_eq!(r.data().len(), 12);
    }

    #[test]
    fn value_from_flat_ranks() {
        let sc = TensorSpec {
            name: "a".into(),
            shape: vec![],
        };
        assert!(matches!(
            Value::from_flat(&sc, vec![1.0]).unwrap(),
            Value::Scalar(_)
        ));
        let ve = TensorSpec {
            name: "b".into(),
            shape: vec![3],
        };
        assert!(matches!(
            Value::from_flat(&ve, vec![1.0, 2.0, 3.0]).unwrap(),
            Value::Vector(_)
        ));
        let ma = TensorSpec {
            name: "c".into(),
            shape: vec![2, 2],
        };
        let m = Value::from_flat(&ma, vec![1.0; 4]).unwrap();
        assert_eq!(m.into_matrix().unwrap().shape(), (2, 2));
        // wrong element count rejected
        assert!(Value::from_flat(&ve, vec![1.0]).is_err());
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Scalar(3.0).as_scalar().unwrap(), 3.0);
        assert!(Value::Vector(vec![]).as_scalar().is_err());
        assert!(Value::Scalar(1.0).into_matrix().is_err());
    }
}
