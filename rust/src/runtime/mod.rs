//! PJRT runtime: load AOT artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin) behind a typed,
//! manifest-validated interface:
//!
//! * [`manifest`] — parse `artifacts/manifest.json` (input/output specs
//!   emitted by `python/compile/aot.py`);
//! * [`client`] — `Runtime`: PJRT client + per-artifact compiled
//!   executable cache; [`client::Executable::run`] validates shapes
//!   against the manifest before dispatch and returns `Matrix`/scalars.
//!
//! HLO *text* is the interchange format (see `aot.py` for why), parsed
//! with `HloModuleProto::from_text_file` and compiled at first use.

pub mod client;
pub mod manifest;

pub use client::{ArgRef, Executable, Runtime, Value};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
