//! PJRT runtime: load AOT artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin) behind a typed,
//! manifest-validated interface:
//!
//! * [`manifest`] — parse `artifacts/manifest.json` (input/output specs
//!   emitted by `python/compile/aot.py`);
//! * [`values`] — the typed `Value`/`ArgRef` marshalling layer shared by
//!   both client builds;
//! * [`client`] — `Runtime`: PJRT client + per-artifact compiled
//!   executable cache; `Executable::run` validates shapes against the
//!   manifest before dispatch and returns `Matrix`/scalars.
//!
//! The PJRT path is gated behind the `hlo` cargo feature: without it (the
//! offline default) `client` resolves to a stub with the same surface
//! whose runtime constructor reports a clear "backend unavailable" error,
//! so `--backend native` keeps working and nothing upstream needs cfg'ing.
//!
//! HLO *text* is the interchange format (see `aot.py` for why), parsed
//! with `HloModuleProto::from_text_file` and compiled at first use.

pub mod manifest;
pub mod values;

#[cfg(feature = "hlo")]
pub mod client;

#[cfg(not(feature = "hlo"))]
#[path = "client_stub.rs"]
pub mod client;

pub use client::{Executable, Runtime};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use values::{ArgRef, ExecStats, Value};
