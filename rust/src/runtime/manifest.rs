//! Artifact manifest (`artifacts/manifest.json`).
//!
//! The AOT compiler records, for every lowered graph, the positional
//! input and output tensor specs. The runtime validates every execution
//! against these — a shape mismatch is caught with a readable error
//! *before* PJRT sees it, and the coordinator sizes its buffers from the
//! manifest instead of parsing HLO.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Shape + name of one graph input/output (always f32 in this project).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    /// Dimensions; empty = scalar.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_scalar(&self) -> bool {
        self.shape.is_empty()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let name = v
            .req("name")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .ok_or_else(|| anyhow!("spec name not a string"))?
            .to_string();
        let shape = v
            .req("shape")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("spec '{name}': bad shape"))?;
        let dtype = v.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32");
        if dtype != "f32" {
            bail!("spec '{name}': unsupported dtype {dtype}");
        }
        Ok(TensorSpec { name, shape })
    }
}

/// One artifact: HLO file + positional I/O contract.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }
}

/// Task metadata mirrored from `python/compile/model.py::TASKS`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskMeta {
    pub batch: usize,
    pub n_in: usize,
    pub n_out: usize,
    pub loss: String,
}

/// MLP metadata for the monolithic e2e artifacts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MlpMeta {
    pub layers: Vec<usize>,
    pub batch: usize,
    pub k: usize,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory containing the manifest (artifact paths are relative).
    pub dir: PathBuf,
    pub tasks: BTreeMap<String, TaskMeta>,
    pub mlp: MlpMeta,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse from in-memory JSON (exposed for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = root
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest: missing version"))?;
        if version != 1 {
            bail!("manifest: unsupported version {version}");
        }

        let mut tasks = BTreeMap::new();
        for (name, t) in root
            .req("tasks")
            .map_err(|e| anyhow!("{e}"))?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: tasks not an object"))?
        {
            let get = |k: &str| -> Result<usize> {
                t.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("task {name}: missing {k}"))
            };
            tasks.insert(
                name.clone(),
                TaskMeta {
                    batch: get("batch")?,
                    n_in: get("n_in")?,
                    n_out: get("n_out")?,
                    loss: t
                        .get("loss")
                        .and_then(|v| v.as_str())
                        .unwrap_or("mse")
                        .to_string(),
                },
            );
        }

        let mlp_j = root.req("mlp").map_err(|e| anyhow!("{e}"))?;
        let mlp = MlpMeta {
            layers: mlp_j
                .req("layers")
                .map_err(|e| anyhow!("{e}"))?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("manifest: bad mlp.layers"))?,
            batch: mlp_j
                .get("batch")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest: bad mlp.batch"))?,
            k: mlp_j
                .get("k")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest: bad mlp.k"))?,
        };

        let mut artifacts = BTreeMap::new();
        for (name, a) in root
            .req("artifacts")
            .map_err(|e| anyhow!("{e}"))?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: artifacts not an object"))?
        {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.req(key)
                    .map_err(|e| anyhow!("artifact {name}: {e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("artifact {name}: {key} not an array"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(
                        a.req("file")
                            .map_err(|e| anyhow!("{e}"))?
                            .as_str()
                            .ok_or_else(|| anyhow!("artifact {name}: bad file"))?,
                    ),
                    sha256: a
                        .get("sha256")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            tasks,
            mlp,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn task(&self, name: &str) -> Result<&TaskMeta> {
        self.tasks
            .get(name)
            .ok_or_else(|| anyhow!("task '{name}' not in manifest"))
    }

    /// Verify every artifact file exists on disk.
    pub fn check_files(&self) -> Result<()> {
        for a in self.artifacts.values() {
            if !a.file.exists() {
                bail!("artifact file missing: {}", a.file.display());
            }
        }
        Ok(())
    }

    /// Default artifacts directory: `$REPRO_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root (walking up from cwd).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("REPRO_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // walk up from cwd looking for artifacts/manifest.json
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        for _ in 0..5 {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !dir.pop() {
                break;
            }
        }
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "tasks": {"energy": {"batch": 144, "n_in": 16, "n_out": 1, "loss": "mse"}},
      "mlp": {"layers": [784, 1024, 10], "batch": 128, "k": 32},
      "artifacts": {
        "energy_eval": {
          "file": "energy_eval.hlo.txt",
          "sha256": "abc",
          "inputs": [
            {"name": "x", "shape": [144, 16], "dtype": "f32"},
            {"name": "eta", "shape": [], "dtype": "f32"}
          ],
          "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.tasks["energy"].batch, 144);
        assert_eq!(m.mlp.layers, vec![784, 1024, 10]);
        let a = m.artifact("energy_eval").unwrap();
        assert_eq!(a.inputs[0].shape, vec![144, 16]);
        assert!(a.inputs[1].is_scalar());
        assert_eq!(a.input_index("eta"), Some(1));
        assert_eq!(a.output_index("loss"), Some(0));
        assert_eq!(a.file, Path::new("/tmp/arts/energy_eval.hlo.txt"));
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(Path::new("."), &bad).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"dtype\": \"f32\"", "\"dtype\": \"f64\"");
        assert!(Manifest::parse(Path::new("."), &bad).is_err());
    }

    #[test]
    fn missing_artifact_error() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.task("nope").is_err());
    }

    #[test]
    fn num_elements() {
        let t = TensorSpec {
            name: "x".into(),
            shape: vec![3, 4],
        };
        assert_eq!(t.num_elements(), 12);
        let s = TensorSpec {
            name: "eta".into(),
            shape: vec![],
        };
        assert_eq!(s.num_elements(), 1);
        assert!(s.is_scalar());
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 9);
            m.check_files().unwrap();
            // the paper's two tasks must be present
            assert!(m.tasks.contains_key("energy"));
            assert!(m.tasks.contains_key("mnist"));
        }
    }
}
