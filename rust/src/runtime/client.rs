//! PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! [`Runtime`] owns one `PjRtClient` (CPU) and a lazily-populated cache of
//! compiled executables keyed by artifact name. [`Executable::run`]
//! validates argument shapes against the manifest, marshals `Matrix`/
//! scalar values into `xla::Literal`s, executes, and unpacks the output
//! tuple back into typed values, accumulating wall-clock stats per
//! artifact (surfaced by `repro inspect-artifacts` and the §Perf pass).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest, TensorSpec};
use crate::tensor::Matrix;

/// A typed value crossing the Rust ⇄ PJRT boundary.
#[derive(Debug, Clone)]
pub enum Value {
    Scalar(f32),
    Vector(Vec<f32>),
    Matrix(Matrix),
}

/// Borrowed argument for [`Executable::run_ref`] — lets the hot path feed
/// model state without cloning matrices into [`Value`]s first (§Perf).
#[derive(Debug, Clone, Copy)]
pub enum ArgRef<'a> {
    Scalar(f32),
    Vector(&'a [f32]),
    Matrix(&'a Matrix),
}

impl<'a> ArgRef<'a> {
    fn shape(&self) -> Vec<usize> {
        match self {
            ArgRef::Scalar(_) => vec![],
            ArgRef::Vector(v) => vec![v.len()],
            ArgRef::Matrix(m) => vec![m.rows(), m.cols()],
        }
    }

    fn data(&self) -> &[f32] {
        match self {
            ArgRef::Scalar(v) => std::slice::from_ref(v),
            ArgRef::Vector(v) => v,
            ArgRef::Matrix(m) => m.data(),
        }
    }
}

impl<'a> From<&'a Value> for ArgRef<'a> {
    fn from(v: &'a Value) -> Self {
        match v {
            Value::Scalar(s) => ArgRef::Scalar(*s),
            Value::Vector(v) => ArgRef::Vector(v),
            Value::Matrix(m) => ArgRef::Matrix(m),
        }
    }
}

impl<'a> From<&'a Matrix> for ArgRef<'a> {
    fn from(m: &'a Matrix) -> Self {
        ArgRef::Matrix(m)
    }
}

impl<'a> From<&'a [f32]> for ArgRef<'a> {
    fn from(v: &'a [f32]) -> Self {
        ArgRef::Vector(v)
    }
}

impl<'a> From<&'a Vec<f32>> for ArgRef<'a> {
    fn from(v: &'a Vec<f32>) -> Self {
        ArgRef::Vector(v)
    }
}

impl From<f32> for ArgRef<'static> {
    fn from(v: f32) -> Self {
        ArgRef::Scalar(v)
    }
}

impl Value {
    pub fn as_scalar(&self) -> Result<f32> {
        match self {
            Value::Scalar(v) => Ok(*v),
            _ => bail!("expected scalar, got {self:?}"),
        }
    }

    pub fn as_vector(&self) -> Result<&[f32]> {
        match self {
            Value::Vector(v) => Ok(v),
            _ => bail!("expected vector"),
        }
    }

    pub fn into_matrix(self) -> Result<Matrix> {
        match self {
            Value::Matrix(m) => Ok(m),
            _ => bail!("expected matrix"),
        }
    }

    pub fn into_vector(self) -> Result<Vec<f32>> {
        match self {
            Value::Vector(v) => Ok(v),
            _ => bail!("expected vector"),
        }
    }

    /// Build from a spec + flat data (output unmarshalling).
    fn from_flat(spec: &TensorSpec, data: Vec<f32>) -> Result<Value> {
        if data.len() != spec.num_elements() {
            bail!(
                "output '{}': got {} elements, expected {}",
                spec.name,
                data.len(),
                spec.num_elements()
            );
        }
        Ok(match spec.shape.len() {
            0 => Value::Scalar(data[0]),
            1 => Value::Vector(data),
            2 => Value::Matrix(Matrix::from_vec(spec.shape[0], spec.shape[1], data)),
            n => bail!("output '{}': rank {n} unsupported", spec.name),
        })
    }
}

impl From<f32> for Value {
    fn from(v: f32) -> Self {
        Value::Scalar(v)
    }
}

impl From<Vec<f32>> for Value {
    fn from(v: Vec<f32>) -> Self {
        Value::Vector(v)
    }
}

impl From<Matrix> for Value {
    fn from(m: Matrix) -> Self {
        Value::Matrix(m)
    }
}

impl From<&Matrix> for Value {
    fn from(m: &Matrix) -> Self {
        Value::Matrix(m.clone())
    }
}

/// Cumulative execution stats for one artifact.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ns: u64,
    pub compile_ns: u64,
}

impl ExecStats {
    pub fn mean_us(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.calls as f64 / 1e3
        }
    }
}

/// One compiled artifact.
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    stats: RefCell<ExecStats>,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    /// Execute with positional arguments; validates shapes against the
    /// manifest and returns outputs in manifest order.
    pub fn run(&self, args: &[Value]) -> Result<Vec<Value>> {
        let refs: Vec<ArgRef<'_>> = args.iter().map(ArgRef::from).collect();
        self.run_ref(&refs)
    }

    /// Zero-clone variant of [`Executable::run`]: arguments are borrowed,
    /// so model state crosses into PJRT with exactly one copy (the
    /// literal construction) instead of two.
    pub fn run_ref(&self, args: &[ArgRef<'_>]) -> Result<Vec<Value>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} args, expected {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(self.spec.inputs.iter()) {
            let shape = arg.shape();
            if shape != spec.shape {
                bail!(
                    "{}: input '{}' shape {:?}, expected {:?}",
                    self.spec.name,
                    spec.name,
                    shape,
                    spec.shape
                );
            }
            let lit = xla::Literal::vec1(arg.data());
            let lit = if spec.is_scalar() {
                lit.reshape(&[])
                    .with_context(|| format!("reshaping scalar '{}'", spec.name))?
            } else {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .with_context(|| format!("reshaping '{}'", spec.name))?
            };
            literals.push(lit);
        }

        let t = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.spec.name))?;
        // aot.py lowers with return_tuple=True ⇒ always a tuple
        let parts = tuple
            .to_tuple()
            .with_context(|| format!("untupling result of {}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(self.spec.outputs.iter()) {
            let data = lit
                .to_vec::<f32>()
                .with_context(|| format!("reading output '{}'", ospec.name))?;
            out.push(Value::from_flat(ospec, data)?);
        }
        let dt = t.elapsed().as_nanos() as u64;
        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.total_ns += dt;
        Ok(out)
    }
}

/// PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// CPU PJRT client over the given artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
        })
    }

    /// Like [`Runtime::new`] with the default artifacts location.
    pub fn from_default_artifacts() -> Result<Runtime> {
        Self::new(&Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the named artifact.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let compile_ns = t.elapsed().as_nanos() as u64;
        let executable = Rc::new(Executable {
            spec,
            exe,
            stats: RefCell::new(ExecStats {
                compile_ns,
                ..Default::default()
            }),
        });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Compile every artifact in the manifest (warm-up / smoke check).
    pub fn load_all(&self) -> Result<Vec<(String, ExecStats)>> {
        let names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        let mut out = Vec::new();
        for n in names {
            let e = self.load(&n)?;
            out.push((n, e.stats()));
        }
        Ok(out)
    }

    /// Stats snapshot for all loaded artifacts.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        self.cache
            .borrow()
            .iter()
            .map(|(k, v)| (k.clone(), v.stats()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    #[test]
    fn argref_shape_data() {
        let v = Value::Scalar(2.0);
        let r = ArgRef::from(&v);
        assert!(r.shape().is_empty());
        assert_eq!(r.data(), &[2.0]);
        let vec_val = vec![1.0f32, 2.0];
        let r = ArgRef::from(&vec_val);
        assert_eq!(r.shape(), vec![2]);
        assert_eq!(r.data().len(), 2);
        let m = Matrix::zeros(3, 4);
        let r = ArgRef::from(&m);
        assert_eq!(r.shape(), vec![3, 4]);
        assert_eq!(r.data().len(), 12);
    }

    #[test]
    fn value_from_flat_ranks() {
        let sc = TensorSpec {
            name: "a".into(),
            shape: vec![],
        };
        assert!(matches!(
            Value::from_flat(&sc, vec![1.0]).unwrap(),
            Value::Scalar(_)
        ));
        let ve = TensorSpec {
            name: "b".into(),
            shape: vec![3],
        };
        assert!(matches!(
            Value::from_flat(&ve, vec![1.0, 2.0, 3.0]).unwrap(),
            Value::Vector(_)
        ));
        let ma = TensorSpec {
            name: "c".into(),
            shape: vec![2, 2],
        };
        let m = Value::from_flat(&ma, vec![1.0; 4]).unwrap();
        assert_eq!(m.into_matrix().unwrap().shape(), (2, 2));
        // wrong element count rejected
        assert!(Value::from_flat(&ve, vec![1.0]).is_err());
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Scalar(3.0).as_scalar().unwrap(), 3.0);
        assert!(Value::Vector(vec![]).as_scalar().is_err());
        assert!(Value::Scalar(1.0).into_matrix().is_err());
    }

    // Execution against real artifacts is covered by rust/tests/ (needs
    // `make artifacts`); unit scope here is marshalling only.
}
