//! PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! Compiled only with the `hlo` cargo feature (the default offline build
//! uses the stub in `client_stub.rs` instead). [`Runtime`] owns one
//! `PjRtClient` (CPU) and a lazily-populated cache of compiled
//! executables keyed by artifact name. [`Executable::run`] validates
//! argument shapes against the manifest, marshals `Matrix`/scalar values
//! into `xla::Literal`s, executes, and unpacks the output tuple back into
//! typed values, accumulating wall-clock stats per artifact (surfaced by
//! `repro inspect-artifacts` and the §Perf pass).

// Clock reads are deliberate here (compile/execute timing diagnostics) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::values::{ArgRef, ExecStats, Value};

/// One compiled artifact.
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    stats: RefCell<ExecStats>,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    /// Execute with positional arguments; validates shapes against the
    /// manifest and returns outputs in manifest order.
    pub fn run(&self, args: &[Value]) -> Result<Vec<Value>> {
        let refs: Vec<ArgRef<'_>> = args.iter().map(ArgRef::from).collect();
        self.run_ref(&refs)
    }

    /// Zero-clone variant of [`Executable::run`]: arguments are borrowed,
    /// so model state crosses into PJRT with exactly one copy (the
    /// literal construction) instead of two.
    pub fn run_ref(&self, args: &[ArgRef<'_>]) -> Result<Vec<Value>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} args, expected {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(self.spec.inputs.iter()) {
            let shape = arg.shape();
            if shape != spec.shape {
                bail!(
                    "{}: input '{}' shape {:?}, expected {:?}",
                    self.spec.name,
                    spec.name,
                    shape,
                    spec.shape
                );
            }
            let lit = xla::Literal::vec1(arg.data());
            let lit = if spec.is_scalar() {
                lit.reshape(&[])
                    .with_context(|| format!("reshaping scalar '{}'", spec.name))?
            } else {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .with_context(|| format!("reshaping '{}'", spec.name))?
            };
            literals.push(lit);
        }

        let t = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.spec.name))?;
        // aot.py lowers with return_tuple=True ⇒ always a tuple
        let parts = tuple
            .to_tuple()
            .with_context(|| format!("untupling result of {}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(self.spec.outputs.iter()) {
            let data = lit
                .to_vec::<f32>()
                .with_context(|| format!("reading output '{}'", ospec.name))?;
            out.push(Value::from_flat(ospec, data)?);
        }
        let dt = t.elapsed().as_nanos() as u64;
        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.total_ns += dt;
        Ok(out)
    }
}

/// PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// CPU PJRT client over the given artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
        })
    }

    /// Like [`Runtime::new`] with the default artifacts location.
    pub fn from_default_artifacts() -> Result<Runtime> {
        Self::new(&Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) the named artifact.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let compile_ns = t.elapsed().as_nanos() as u64;
        let executable = Rc::new(Executable {
            spec,
            exe,
            stats: RefCell::new(ExecStats {
                compile_ns,
                ..Default::default()
            }),
        });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Compile every artifact in the manifest (warm-up / smoke check).
    pub fn load_all(&self) -> Result<Vec<(String, ExecStats)>> {
        let names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        let mut out = Vec::new();
        for n in names {
            let e = self.load(&n)?;
            out.push((n, e.stats()));
        }
        Ok(out)
    }

    /// Stats snapshot for all loaded artifacts.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        self.cache
            .borrow()
            .iter()
            .map(|(k, v)| (k.clone(), v.stats()))
            .collect()
    }
}

// Marshalling unit tests live in `values.rs`; execution-path tests live
// in rust/tests/ (they need the built artifacts).
