//! Optimizers consuming the AOP-approximated gradient (paper Remark 1:
//! "Mem-AOP-GD is independent from the optimizer, since it only aids the
//! approximate computation of the gradient weight").
//!
//! Here the engine produces the *raw* approximate gradient (memory folded
//! with η_t = 1, so Ŵ* estimates `X^T G` itself) and the optimizer owns
//! the step size: plain SGD reproduces Algorithm 1 exactly; momentum and
//! Adam exercise the Remark-1 claim that the approximation composes with
//! stateful optimizers (Adam's second moment is driven by the same
//! approximate gradient).

use crate::tensor::Matrix;

/// First-order optimizer over a single weight matrix + bias.
#[derive(Debug, Clone)]
pub enum Optimizer {
    /// `W -= lr * g`.
    Sgd { lr: f32 },
    /// Heavy-ball: `v = beta v + g; W -= lr v`.
    Momentum { lr: f32, beta: f32 },
    /// Adam (Kingma & Ba, ref. [14] of the paper).
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl Optimizer {
    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Sgd { .. } => "sgd",
            Optimizer::Momentum { .. } => "momentum",
            Optimizer::Adam { .. } => "adam",
        }
    }

    pub fn parse(s: &str, lr: f32) -> Option<Optimizer> {
        Some(match s {
            "sgd" => Optimizer::Sgd { lr },
            "momentum" => Optimizer::Momentum { lr, beta: 0.9 },
            "adam" => Optimizer::adam(lr),
            _ => return None,
        })
    }
}

/// Mutable optimizer state for one (W, b) pair.
#[derive(Debug, Clone)]
pub struct OptState {
    /// First moment / velocity for W (momentum, adam).
    m_w: Option<Matrix>,
    /// Second moment for W (adam).
    v_w: Option<Matrix>,
    m_b: Vec<f32>,
    v_b: Vec<f32>,
    /// Step counter (adam bias correction).
    t: u32,
}

impl OptState {
    pub fn new(n: usize, p: usize) -> OptState {
        OptState {
            m_w: Some(Matrix::zeros(n, p)),
            v_w: Some(Matrix::zeros(n, p)),
            m_b: vec![0.0; p],
            v_b: vec![0.0; p],
            t: 0,
        }
    }

    /// Apply one update with gradient estimates `gw` (matrix) and `gb`
    /// (vector), mutating `w` and `b` in place.
    pub fn apply(
        &mut self,
        opt: &Optimizer,
        w: &mut Matrix,
        b: &mut [f32],
        gw: &Matrix,
        gb: &[f32],
    ) {
        self.t += 1;
        match *opt {
            Optimizer::Sgd { lr } => {
                w.axpy(-lr, gw);
                for (bv, &g) in b.iter_mut().zip(gb.iter()) {
                    *bv -= lr * g;
                }
            }
            Optimizer::Momentum { lr, beta } => {
                let v = self.m_w.as_mut().unwrap();
                for (vv, &g) in v.data_mut().iter_mut().zip(gw.data().iter()) {
                    *vv = beta * *vv + g;
                }
                w.axpy(-lr, v);
                for i in 0..b.len() {
                    self.m_b[i] = beta * self.m_b[i] + gb[i];
                    b[i] -= lr * self.m_b[i];
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                let bc1 = 1.0 - beta1.powi(self.t as i32);
                let bc2 = 1.0 - beta2.powi(self.t as i32);
                let m = self.m_w.as_mut().unwrap();
                let v = self.v_w.as_mut().unwrap();
                for ((wv, &g), (mv, vv)) in w
                    .data_mut()
                    .iter_mut()
                    .zip(gw.data().iter())
                    .zip(m.data_mut().iter_mut().zip(v.data_mut().iter_mut()))
                {
                    *mv = beta1 * *mv + (1.0 - beta1) * g;
                    *vv = beta2 * *vv + (1.0 - beta2) * g * g;
                    let mhat = *mv / bc1;
                    let vhat = *vv / bc2;
                    *wv -= lr * mhat / (vhat.sqrt() + eps);
                }
                for i in 0..b.len() {
                    self.m_b[i] = beta1 * self.m_b[i] + (1.0 - beta1) * gb[i];
                    self.v_b[i] = beta2 * self.v_b[i] + (1.0 - beta2) * gb[i] * gb[i];
                    let mhat = self.m_b[i] / bc1;
                    let vhat = self.v_b[i] / bc2;
                    b[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn quad_grad(w: &Matrix, target: &Matrix) -> Matrix {
        // grad of 0.5||w - target||^2
        w.sub(target)
    }

    #[test]
    fn sgd_matches_closed_form() {
        let mut w = Matrix::full(2, 2, 1.0);
        let mut b = vec![1.0f32];
        let g = Matrix::full(2, 2, 0.5);
        let mut st = OptState::new(2, 2);
        st.apply(&Optimizer::Sgd { lr: 0.1 }, &mut w, &mut b, &g, &[0.5]);
        assert!((w[(0, 0)] - 0.95).abs() < 1e-6);
        assert!((b[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut w = Matrix::zeros(1, 1);
        let mut b = vec![];
        let g = Matrix::full(1, 1, 1.0);
        let opt = Optimizer::Momentum { lr: 1.0, beta: 0.5 };
        let mut st = OptState::new(1, 1);
        st.apply(&opt, &mut w, &mut b, &g, &[]);
        assert!((w[(0, 0)] + 1.0).abs() < 1e-6); // v=1
        st.apply(&opt, &mut w, &mut b, &g, &[]);
        assert!((w[(0, 0)] + 2.5).abs() < 1e-6); // v=1.5
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let target = Matrix::from_vec(2, 1, vec![3.0, -2.0]);
        let mut w = Matrix::zeros(2, 1);
        let mut b = vec![];
        let opt = Optimizer::adam(0.1);
        let mut st = OptState::new(2, 1);
        for _ in 0..500 {
            let g = quad_grad(&w, &target);
            st.apply(&opt, &mut w, &mut b, &g, &[]);
        }
        assert!(w.max_abs_diff(&target) < 0.05, "{w:?}");
    }

    #[test]
    fn adam_invariant_to_gradient_scale() {
        // Adam's update direction is scale-free: scaled gradients give
        // (nearly) the same trajectory — relevant because the AOP
        // estimate rescales gradient magnitude per step.
        let target = Matrix::from_vec(1, 1, vec![1.0]);
        let run = |scale: f32| {
            let mut w = Matrix::zeros(1, 1);
            let mut b = vec![];
            let opt = Optimizer::adam(0.05);
            let mut st = OptState::new(1, 1);
            for _ in 0..100 {
                let g = quad_grad(&w, &target).scale(scale);
                st.apply(&opt, &mut w, &mut b, &g, &[]);
            }
            w[(0, 0)]
        };
        assert!((run(1.0) - run(10.0)).abs() < 0.05);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Optimizer::parse("adam", 0.1).unwrap().name(), "adam");
        assert_eq!(Optimizer::parse("sgd", 0.1).unwrap().name(), "sgd");
        assert_eq!(Optimizer::parse("momentum", 0.1).unwrap().name(), "momentum");
        assert!(Optimizer::parse("lbfgs", 0.1).is_none());
    }

    #[test]
    fn aop_engine_with_adam_trains() {
        // Remark 1 end-to-end: Adam fed by the Mem-AOP gradient estimate.
        use crate::aop::engine::AopEngine;
        use crate::aop::Policy;
        use crate::model::LossKind;
        use crate::tensor::init;
        let mut rng = Rng::new(0);
        let teacher = Matrix::from_fn(8, 1, |_, _| rng.normal());
        let x = Matrix::from_fn(32, 8, |_, _| rng.normal());
        let y = x.matmul(&teacher);
        let mut e = AopEngine::new(
            init::glorot_uniform(&mut rng, 8, 1),
            LossKind::Mse,
            32,
            Policy::TopK,
            8,
            true,
        );
        let opt = Optimizer::adam(0.05);
        let mut st = OptState::new(8, 1);
        let before = e.evaluate(&x, &y).0;
        for _ in 0..300 {
            e.step_with_optimizer(&x, &y, &opt, &mut st, &mut rng);
        }
        let after = e.evaluate(&x, &y).0;
        assert!(after < before * 0.05, "before={before} after={after}");
    }
}
