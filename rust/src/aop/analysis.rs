//! Empirical approximation-error analysis — the quantitative side of the
//! theory the paper leaves as future work (Sec. V).
//!
//! Drineas-Kannan-Mahoney (ref. [8]) bound the AOP error as
//! `E‖C − Ĉ‖_F = O(‖A‖_F ‖B‖_F / √c)` for weighted sampling with
//! replacement. This module measures, for every policy:
//!
//!   * the one-shot relative error `‖Ŵ* − W*‖_F / ‖W*‖_F` as a function
//!     of K (the √K decay, Fig.-style sweep via `repro approx-error`);
//!   * the *effective* error under error feedback — how much deferred
//!     gradient mass the memory recovers over a window of steps.

use crate::aop::policy::{self, Policy};
use crate::tensor::{ops, rng::Rng, Matrix};

/// One measurement cell.
#[derive(Debug, Clone)]
pub struct ErrorPoint {
    pub policy: Policy,
    pub k: usize,
    pub m: usize,
    /// Mean relative Frobenius error over the trials.
    pub rel_error: f64,
    /// Standard deviation over trials.
    pub sd: f64,
}

/// One-shot approximation error of `out_K` on fixed (X, G): mean ± sd of
/// `‖Ŵ* − X^T G‖_F / ‖X^T G‖_F` over `trials` policy draws.
pub fn one_shot_error(
    x: &Matrix,
    g: &Matrix,
    policy: Policy,
    k: usize,
    trials: usize,
    rng: &mut Rng,
) -> ErrorPoint {
    let exact = ops::matmul_tn(x, g);
    let exact_fro = exact.frobenius() as f64;
    let scores = ops::norm_product_scores(x, g);
    let mut errs = Vec::with_capacity(trials);
    for _ in 0..trials {
        let sel = policy::select(policy, &scores, k, false, rng);
        let approx = ops::masked_outer(x, g, &sel.sel_scale);
        errs.push(approx.sub(&exact).frobenius() as f64 / exact_fro.max(1e-12));
        if !policy.is_stochastic() {
            break; // deterministic: one trial suffices
        }
    }
    let n = errs.len() as f64;
    let mean = errs.iter().sum::<f64>() / n;
    let var = errs.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / n;
    ErrorPoint {
        policy,
        k,
        m: x.rows(),
        rel_error: mean,
        sd: var.sqrt(),
    }
}

/// Sweep all figure policies across a K grid on synthetic (X, G) with the
/// given row-norm skew (`skew = 0` ⇒ iid rows; larger ⇒ a few heavy rows,
/// the regime where topK/weightedK beat randK).
pub fn error_sweep(
    m: usize,
    n: usize,
    p: usize,
    ks: &[usize],
    skew: f32,
    trials: usize,
    seed: u64,
) -> Vec<ErrorPoint> {
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(m, n, |r, _| {
        let scale = (1.0 + skew * r as f32 / m as f32).powi(2);
        rng.normal() * scale
    });
    let g = Matrix::from_fn(m, p, |_, _| rng.normal());
    let mut out = Vec::new();
    for &k in ks {
        for pol in [Policy::TopK, Policy::WeightedK, Policy::RandK, Policy::WeightedKReplacement] {
            out.push(one_shot_error(&x, &g, pol, k, trials, &mut rng));
        }
    }
    out
}

/// Deferred-flush identity: select K of M outer products of (X, G), stash
/// the unselected rows in the memory (alg. lines 8-9), then *flush* the
/// memory (one step with zero fresh data, full selection). Returns the
/// relative error of `applied + flushed` vs the exact `X^T G`.
///
/// With memory this is exactly 0 (the unselected rows' outer products are
/// recovered verbatim — the mask-complement identity behind eq. (7)'s
/// `m^X,T m^G` term); without memory the unselected mass is lost and the
/// error equals the one-shot approximation error. Note this is *sharper*
/// than gradient-level error feedback can claim: over multiple fresh
/// batches the factor-level memory also produces the `m^X,T G + X^T m^G`
/// cross terms, which the paper conjectures act as useful stale gradients
/// (Sec. III) — those are measured by the training curves, not here.
pub fn deferred_flush_error(
    x: &Matrix,
    g: &Matrix,
    policy: Policy,
    k: usize,
    memory: bool,
    rng: &mut Rng,
) -> f64 {
    use crate::aop::memory::MemoryState;
    let (m, n) = x.shape();
    let p = g.cols();
    let exact = ops::matmul_tn(x, g);
    let mut mem = MemoryState::new(m, n, p, memory);

    // step 1: approximate on the real batch
    let (xhat, ghat) = mem.fold(x, g, 1.0);
    let scores = ops::norm_product_scores(&xhat, &ghat);
    let sel = policy::select(policy, &scores, k, memory, rng);
    let mut applied = ops::masked_outer(&xhat, &ghat, &sel.sel_scale);
    mem.update(&xhat, &ghat, &sel.keep);

    // step 2: flush — zero fresh data, select everything
    let zero_x = Matrix::zeros(m, n);
    let zero_g = Matrix::zeros(m, p);
    let (fx, fg) = mem.fold(&zero_x, &zero_g, 1.0);
    let ones = vec![1.0f32; m];
    applied.axpy(1.0, &ops::masked_outer(&fx, &fg, &ones));

    applied.sub(&exact).frobenius() as f64 / (exact.frobenius() as f64).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (
            Matrix::from_fn(m, n, |_, _| rng.normal()),
            Matrix::from_fn(m, p, |_, _| rng.normal()),
        )
    }

    #[test]
    fn error_is_zero_at_full_k() {
        let (x, g) = data(32, 8, 4, 0);
        let mut rng = Rng::new(1);
        for pol in [Policy::TopK, Policy::RandK, Policy::WeightedK] {
            let e = one_shot_error(&x, &g, pol, 32, 5, &mut rng);
            assert!(e.rel_error < 1e-6, "{pol:?}: {}", e.rel_error);
        }
    }

    #[test]
    fn error_decreases_with_k() {
        let (x, g) = data(64, 16, 4, 2);
        let mut rng = Rng::new(3);
        let mut prev = f64::INFINITY;
        for k in [4usize, 16, 32, 56] {
            let e = one_shot_error(&x, &g, Policy::RandK, k, 40, &mut rng);
            assert!(e.rel_error < prev + 0.05, "K={k}: {} vs {prev}", e.rel_error);
            prev = e.rel_error;
        }
    }

    #[test]
    fn topk_beats_randk_on_skewed_rows() {
        // a few heavy rows carry most of the product: topK must capture
        // far more of it than uniform sampling
        let pts = error_sweep(64, 12, 6, &[8], 6.0, 40, 4);
        let get = |p: Policy| pts.iter().find(|e| e.policy == p).unwrap().rel_error;
        assert!(
            get(Policy::TopK) < 0.85 * get(Policy::RandK),
            "topk {} vs randk {}",
            get(Policy::TopK),
            get(Policy::RandK)
        );
        assert!(get(Policy::WeightedK) < get(Policy::RandK));
    }

    #[test]
    fn replacement_scaling_trades_bias_for_variance() {
        // eq. (5) is unbiased but high-variance: its sd must exceed the
        // without-replacement policy's on the same draw count
        let (x, g) = data(48, 10, 5, 5);
        let mut rng = Rng::new(6);
        let wo = one_shot_error(&x, &g, Policy::WeightedK, 8, 60, &mut rng);
        let wr = one_shot_error(&x, &g, Policy::WeightedKReplacement, 8, 60, &mut rng);
        assert!(wr.sd > wo.sd, "repl sd {} vs w/o sd {}", wr.sd, wo.sd);
    }

    #[test]
    fn deferred_flush_completes_exact_product() {
        let (x, g) = data(32, 8, 4, 7);
        for pol in [Policy::TopK, Policy::RandK, Policy::WeightedK] {
            let mut r1 = Rng::new(8);
            let mut r2 = Rng::new(8);
            let with_mem = deferred_flush_error(&x, &g, pol, 8, true, &mut r1);
            let without = deferred_flush_error(&x, &g, pol, 8, false, &mut r2);
            // memory recovers the unselected mass exactly (f32 tolerance);
            // without memory the loss equals the one-shot error
            assert!(with_mem < 1e-4, "{pol:?}: flush err {with_mem}");
            assert!(without > 0.3, "{pol:?}: nomem err {without}");
        }
    }

    #[test]
    fn sweep_shapes_and_determinism() {
        let a = error_sweep(32, 8, 2, &[4, 8], 2.0, 10, 9);
        let b = error_sweep(32, 8, 2, &[4, 8], 2.0, 10, 9);
        assert_eq!(a.len(), 8); // 2 Ks × 4 policies
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.rel_error, y.rel_error);
        }
    }
}
