//! FLOP cost model for the computational-reduction claims (Sec. I/IV).
//!
//! The paper's saving is in eq. (2b): evaluating K of M outer products
//! costs `2·K·N·P` FLOPs instead of `2·M·N·P`. The cost model reports the
//! compaction-regime cost (DESIGN.md §8) — what a TPU with in-VMEM row
//! gathering would execute — plus the policy overhead (scores) and the
//! unchanged forward/backward terms, so the end-to-end reduction ratio
//! `R = K/M` claims can be audited per configuration.

/// FLOP breakdown of one Mem-AOP-GD training step on a single dense layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepFlops {
    /// Forward `X W + b`: 2·M·N·P + M·P.
    pub forward: u64,
    /// Loss gradient `G`: ~3·M·P (elementwise).
    pub loss_grad: u64,
    /// Memory folding X̂/Ĝ (lines 3-4): 2·M·(N+P).
    pub fold: u64,
    /// Policy scores ‖X̂_(m)‖‖Ĝ_(m)‖: 2·M·(N+P) + M.
    pub scores: u64,
    /// The AOP weight gradient (eq. (4)): 2·K·N·P  (the headline term).
    pub weight_grad: u64,
    /// Weight/bias/memory updates: N·P + P + M·(N+P).
    pub updates: u64,
}

impl StepFlops {
    pub fn total(&self) -> u64 {
        self.forward + self.loss_grad + self.fold + self.scores + self.weight_grad + self.updates
    }

    /// The paper's headline term alone (backward weight-gradient matmul).
    pub fn backward_only(&self) -> u64 {
        self.weight_grad
    }
}

/// Cost of one step with batch `m`, input dim `n`, output dim `p`, and
/// `k` selected outer products. `k = m` with zero fold/score overhead is
/// the exact-SGD baseline (see [`exact_step`]).
pub fn aop_step(m: usize, n: usize, p: usize, k: usize) -> StepFlops {
    let (m64, n64, p64, k64) = (m as u64, n as u64, p as u64, k as u64);
    StepFlops {
        forward: 2 * m64 * n64 * p64 + m64 * p64,
        loss_grad: 3 * m64 * p64,
        fold: 2 * m64 * (n64 + p64),
        scores: 2 * m64 * (n64 + p64) + m64,
        weight_grad: 2 * k64 * n64 * p64,
        updates: n64 * p64 + p64 + m64 * (n64 + p64),
    }
}

/// Exact back-propagation baseline: full M-row weight gradient, no fold,
/// no scores, no memory writes.
pub fn exact_step(m: usize, n: usize, p: usize) -> StepFlops {
    let (m64, n64, p64) = (m as u64, n as u64, p as u64);
    StepFlops {
        forward: 2 * m64 * n64 * p64 + m64 * p64,
        loss_grad: 3 * m64 * p64,
        fold: 0,
        scores: 0,
        weight_grad: 2 * m64 * n64 * p64,
        updates: n64 * p64 + p64,
    }
}

/// Reduction ratio of the *backward weight-gradient* term (the paper's
/// R = K/M axis in Figs. 2-3).
pub fn backward_reduction(m: usize, n: usize, p: usize, k: usize) -> f64 {
    aop_step(m, n, p, k).backward_only() as f64 / exact_step(m, n, p).backward_only() as f64
}

/// End-to-end step reduction including all overheads (what a deployment
/// actually saves).
pub fn total_reduction(m: usize, n: usize, p: usize, k: usize) -> f64 {
    aop_step(m, n, p, k).total() as f64 / exact_step(m, n, p).total() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backward_ratio_is_k_over_m() {
        for (m, n, p, k) in [(144, 16, 1, 18), (64, 784, 10, 32), (64, 784, 10, 8)] {
            let r = backward_reduction(m, n, p, k);
            assert!((r - k as f64 / m as f64).abs() < 1e-12, "{r}");
        }
    }

    #[test]
    fn exact_equals_aop_with_k_eq_m_on_backward() {
        let a = aop_step(64, 784, 10, 64);
        let e = exact_step(64, 784, 10);
        assert_eq!(a.weight_grad, e.weight_grad);
        assert_eq!(a.forward, e.forward);
    }

    #[test]
    fn total_reduction_below_one_for_small_k_large_np() {
        // mnist shape: N·P = 7840 dominates ⇒ overheads are amortized
        let r = total_reduction(64, 784, 10, 8);
        assert!(r < 0.7, "r={r}");
        // energy shape: N·P = 16 is tiny ⇒ overheads dominate; ratio can
        // exceed the naive K/M but must stay bounded
        let r2 = total_reduction(144, 16, 1, 18);
        assert!(r2 > 0.125 && r2 < 2.0, "r2={r2}");
    }

    #[test]
    fn totals_are_sums() {
        let s = aop_step(10, 5, 3, 4);
        assert_eq!(
            s.total(),
            s.forward + s.loss_grad + s.fold + s.scores + s.weight_grad + s.updates
        );
    }

    #[test]
    fn monotone_in_k() {
        let mut prev = 0u64;
        for k in [1usize, 8, 16, 32, 64] {
            let t = aop_step(64, 784, 10, k).total();
            assert!(t > prev);
            prev = t;
        }
    }
}
