//! Mem-AOP-GD core: selection policies, error-feedback memory, the native
//! single-layer engine, and FLOP accounting.
//!
//! This module is the paper's contribution (Sec. III) as a library:
//!
//! * [`policy`] — `out_K` operators: topK / randK / weightedK (with and
//!   without replacement) plus the exact baseline;
//! * [`memory`] — the `m^X` / `m^G` error-feedback state (alg. lines 3-4,
//!   8-9);
//! * [`engine`] — the single-layer engine surface (a thin adapter over
//!   the [`crate::train`] layer-graph core, where the step itself lives),
//!   the oracle for the HLO path and the baseline comparator for benches;
//! * [`flops`] — exact vs compaction-regime cost model backing the
//!   computational-reduction claims.

pub mod analysis;
pub mod engine;
pub mod flops;
pub mod memory;
pub mod optimizer;
pub mod policy;

pub use engine::{AopEngine, StepStats};
pub use memory::MemoryState;
pub use optimizer::{OptState, Optimizer};
pub use policy::{Policy, Selection};
