//! Native single-layer Mem-AOP-GD engine (Algorithm 1, pure Rust).
//!
//! Structured as the same two phases the HLO path executes —
//! `fwd_score` then `apply` — so `rust/tests/native_vs_hlo.rs` can drive
//! both with identical policy decisions and compare states step-by-step.
//! This engine is also the baseline comparator for the criterion-style
//! benches (native CPU vs PJRT-compiled artifacts).
//!
//! Both phases execute through the [`exec`](crate::exec) subsystem: rows
//! are cut on the fixed shard grid, per-shard kernels run on the
//! executor's worker pool, and cross-row reductions (loss, bias
//! gradient, the AOP weight update) are combined in fixed shard order —
//! so results are bit-identical at every thread count. The plain
//! `fwd_score`/`apply`/`step`/`evaluate` methods are the `threads = 1`
//! special case (an inline [`Executor::serial`]), running the very same
//! code path.

use crate::aop::memory::MemoryState;
use crate::aop::policy::{self, Policy, Selection};
use crate::exec::{reduce, shard, Executor};
use crate::model::loss::{self, LossKind};
use crate::tensor::rng::Rng;
use crate::tensor::{ops, Matrix};

/// Single dense layer `o = x W + b` trained with Mem-AOP-GD — the paper's
/// experimental model for both tasks (16×1 energy, 784×10 mnist).
pub struct AopEngine {
    pub w: Matrix,
    pub b: Vec<f32>,
    pub loss: LossKind,
    pub memory: MemoryState,
    pub policy: Policy,
    pub k: usize,
    /// Use the compaction-regime kernel (K-row loop) instead of the
    /// mask-regime one. Numerically identical for without-replacement
    /// policies; this is the paper's complexity-reduction execution mode.
    pub compact: bool,
}

/// Outputs of the fwd_score phase (mirrors the HLO artifact's outputs).
pub struct FwdScore {
    pub loss: f32,
    pub xhat: Matrix,
    pub ghat: Matrix,
    pub db: Vec<f32>,
    pub scores: Vec<f32>,
}

/// Per-step diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    /// ||Ŵ*||_F of the applied update.
    pub wstar_fro: f32,
    /// Distinct outer products evaluated.
    pub k_effective: usize,
}

impl AopEngine {
    pub fn new(
        w: Matrix,
        loss: LossKind,
        batch: usize,
        policy: Policy,
        k: usize,
        memory_enabled: bool,
    ) -> Self {
        let (n, p) = w.shape();
        AopEngine {
            b: vec![0.0; p],
            w,
            loss,
            memory: MemoryState::new(batch, n, p, memory_enabled),
            policy,
            k,
            compact: true,
        }
    }

    /// Forward output `x W + b`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        x.matmul(&self.w).add_row_broadcast(&self.b)
    }

    /// Phase 1 (mirrors the `*_fwd_score` artifact): forward, loss,
    /// output-gradient, memory folding, policy scores, exact bias grad.
    /// Serial (`threads = 1`) case of [`AopEngine::fwd_score_exec`].
    pub fn fwd_score(&self, x: &Matrix, y: &Matrix, eta: f32) -> FwdScore {
        self.fwd_score_exec(x, y, eta, &Executor::serial())
    }

    /// Phase 1, data-parallel: one shard task per row block computes
    /// forward rows, loss-gradient rows, memory folding, scores and the
    /// partial loss/bias sums; partials reduce in fixed shard order.
    pub fn fwd_score_exec(&self, x: &Matrix, y: &Matrix, eta: f32, exec: &Executor) -> FwdScore {
        let (m, n) = x.shape();
        let p = self.w.cols();
        assert_eq!(y.shape(), (m, p), "target shape");
        let plan = exec.plan(m);
        let se = eta.sqrt();
        let mut xhat = Matrix::zeros(m, n);
        let mut ghat = Matrix::zeros(m, p);
        let mut scores = vec![0.0f32; m];
        let parts: Vec<(f32, Vec<f32>)> = {
            let xh_blocks = shard::RowBlocks::of(&mut xhat, &plan);
            let gh_blocks = shard::RowBlocks::of(&mut ghat, &plan);
            let sc_blocks = shard::RowBlocks::of_slice(&mut scores, 1, &plan);
            exec.map(&plan, |i, rows| {
                let nr = rows.len();
                // shard-local forward + loss-gradient scratch
                let mut o = vec![0.0f32; nr * p];
                shard::forward_rows(x, &self.w, &self.b, rows.clone(), &mut o);
                let loss_part = self.loss.partial_loss(&o, y, rows.clone());
                let mut g = vec![0.0f32; nr * p];
                self.loss.grad_rows(&o, y, rows.clone(), m, &mut g);
                let db_part = shard::col_sums_rows(&g, p);
                // fold memory into the fresh batch (alg. lines 3-4)
                let mut xh = xh_blocks.lock(i);
                shard::fold_rows(x, &self.memory.mem_x, se, rows.clone(), &mut xh);
                let mut gh = gh_blocks.lock(i);
                shard::fold_block(&g, &self.memory.mem_g, se, rows.clone(), &mut gh);
                let mut sc = sc_blocks.lock(i);
                shard::score_rows(&xh, &gh, n, p, &mut sc);
                (loss_part, db_part)
            })
        };
        let loss_total = reduce::sum_f32(parts.iter().map(|(l, _)| *l));
        let loss = self.loss.finish_loss(loss_total, m, p);
        let db_raw = reduce::sum_vecs(p, parts.iter().map(|(_, d)| d.as_slice()));
        let db: Vec<f32> = db_raw.iter().map(|d| eta * d).collect();
        FwdScore {
            loss,
            xhat,
            ghat,
            db,
            scores,
        }
    }

    /// Phase 2 (mirrors the `*_apply` artifact): AOP weight update, exact
    /// bias update, memory update.
    /// Serial (`threads = 1`) case of [`AopEngine::apply_exec`].
    pub fn apply(&mut self, fs: &FwdScore, sel: &Selection) -> StepStats {
        self.apply_exec(fs, sel, &Executor::serial())
    }

    /// Phase 2, data-parallel: each shard accumulates the outer products
    /// of its own selected rows; the partials reduce in fixed shard
    /// order before the (serial, elementwise) weight/bias writes, and the
    /// memory retention rows are rewritten shard-parallel.
    pub fn apply_exec(&mut self, fs: &FwdScore, sel: &Selection, exec: &Executor) -> StepStats {
        let (m, n) = fs.xhat.shape();
        let p = fs.ghat.cols();
        let plan = exec.plan(m);
        let partials: Vec<Option<Matrix>> = if self.compact {
            let pairs = sel.compact_pairs();
            exec.map(&plan, |_, rows| {
                // `pairs` is ascending (Selection contract), so the
                // filtered slice keeps row order within the shard
                let local: Vec<(usize, f32)> = pairs
                    .iter()
                    .copied()
                    .filter(|(r, _)| rows.contains(r))
                    .collect();
                if local.is_empty() {
                    None
                } else {
                    Some(ops::masked_outer_compact(&fs.xhat, &fs.ghat, &local))
                }
            })
        } else {
            exec.map(&plan, |_, rows| {
                Some(ops::masked_outer_range(
                    &fs.xhat,
                    &fs.ghat,
                    &sel.sel_scale,
                    rows,
                ))
            })
        };
        let wstar = reduce::sum_matrices(n, p, partials);
        let wstar_fro = wstar.frobenius();
        self.w.axpy(-1.0, &wstar);
        for (b, d) in self.b.iter_mut().zip(fs.db.iter()) {
            *b -= d;
        }
        if self.memory.enabled {
            let mx_blocks = shard::RowBlocks::of(&mut self.memory.mem_x, &plan);
            let mg_blocks = shard::RowBlocks::of(&mut self.memory.mem_g, &plan);
            exec.run_each(&plan, |i, rows| {
                let mut mx = mx_blocks.lock(i);
                shard::keep_rows(&fs.xhat, &sel.keep, rows.clone(), &mut mx);
                let mut mg = mg_blocks.lock(i);
                shard::keep_rows(&fs.ghat, &sel.keep, rows, &mut mg);
            });
        }
        StepStats {
            loss: fs.loss,
            wstar_fro,
            k_effective: sel.k_effective(),
        }
    }

    /// Full Algorithm-1 step: fwd_score → out_K → apply.
    /// Serial (`threads = 1`) case of [`AopEngine::step_exec`].
    pub fn step(&mut self, x: &Matrix, y: &Matrix, eta: f32, rng: &mut Rng) -> StepStats {
        self.step_exec(x, y, eta, rng, &Executor::serial())
    }

    /// Full data-parallel Algorithm-1 step. The policy decision runs on
    /// the calling thread from the global score vector — selection is
    /// identical at every thread count by construction.
    pub fn step_exec(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        eta: f32,
        rng: &mut Rng,
        exec: &Executor,
    ) -> StepStats {
        let fs = self.fwd_score_exec(x, y, eta, exec);
        let sel = policy::select(
            self.policy,
            &fs.scores,
            self.k.min(fs.scores.len()),
            self.memory.enabled,
            rng,
        );
        self.apply_exec(&fs, &sel, exec)
    }

    /// Validation loss and accuracy.
    /// Serial (`threads = 1`) case of [`AopEngine::evaluate_exec`].
    pub fn evaluate(&self, x: &Matrix, y: &Matrix) -> (f32, f32) {
        self.evaluate_exec(x, y, &Executor::serial())
    }

    /// Validation, data-parallel: per-shard forward + partial loss and
    /// (integer, hence exactly order-free) argmax-agreement counts.
    pub fn evaluate_exec(&self, x: &Matrix, y: &Matrix, exec: &Executor) -> (f32, f32) {
        let m = x.rows();
        let p = self.w.cols();
        let plan = exec.plan(m);
        let parts: Vec<(f32, usize)> = exec.map(&plan, |_, rows| {
            let mut o = vec![0.0f32; rows.len() * p];
            shard::forward_rows(x, &self.w, &self.b, rows.clone(), &mut o);
            (
                self.loss.partial_loss(&o, y, rows.clone()),
                loss::correct_rows(&o, y, rows),
            )
        });
        let loss_total = reduce::sum_f32(parts.iter().map(|(l, _)| *l));
        let correct = reduce::sum_usize(parts.iter().map(|(_, c)| *c));
        (
            self.loss.finish_loss(loss_total, m, p),
            correct as f32 / m as f32,
        )
    }

    /// Remark-1 step: produce the *raw* AOP gradient estimate (memory
    /// folded with η = 1, so Ŵ* ≈ X^T G itself) and hand it to an
    /// external optimizer (SGD / momentum / Adam) that owns the step
    /// size. With `Optimizer::Sgd` this reduces to [`AopEngine::step`]
    /// at the same lr.
    pub fn step_with_optimizer(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        opt: &crate::aop::optimizer::Optimizer,
        state: &mut crate::aop::optimizer::OptState,
        rng: &mut Rng,
    ) -> StepStats {
        let fs = self.fwd_score(x, y, 1.0);
        let sel = policy::select(
            self.policy,
            &fs.scores,
            self.k.min(fs.scores.len()),
            self.memory.enabled,
            rng,
        );
        let gw = if self.compact {
            ops::masked_outer_compact(&fs.xhat, &fs.ghat, &sel.compact_pairs())
        } else {
            ops::masked_outer(&fs.xhat, &fs.ghat, &sel.sel_scale)
        };
        // fwd_score folded η=1, so db is the raw bias gradient
        state.apply(opt, &mut self.w, &mut self.b, &gw, &fs.db);
        self.memory.update(&fs.xhat, &fs.ghat, &sel.keep);
        StepStats {
            loss: fs.loss,
            wstar_fro: gw.frobenius(),
            k_effective: sel.k_effective(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init;

    fn regression_data(rng: &mut Rng, m: usize, n: usize) -> (Matrix, Matrix, Matrix) {
        // linear teacher with noise
        let teacher = Matrix::from_fn(n, 1, |_, _| rng.normal());
        let x = Matrix::from_fn(m, n, |_, _| rng.normal());
        let y = x.matmul(&teacher).map(|v| v); // noiseless: easy target
        (x, y, teacher)
    }

    fn engine(rng: &mut Rng, n: usize, batch: usize, policy: Policy, k: usize, mem: bool) -> AopEngine {
        AopEngine::new(
            init::glorot_uniform(rng, n, 1),
            LossKind::Mse,
            batch,
            policy,
            k,
            mem,
        )
    }

    #[test]
    fn exact_policy_converges_linear_regression() {
        let mut rng = Rng::new(0);
        let (x, y, _) = regression_data(&mut rng, 32, 8);
        let mut e = engine(&mut rng, 8, 32, Policy::Exact, 32, false);
        let before = e.evaluate(&x, &y).0;
        for _ in 0..300 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        let after = e.evaluate(&x, &y).0;
        assert!(after < before * 1e-2, "before={before} after={after}");
    }

    #[test]
    fn topk_with_memory_converges() {
        let mut rng = Rng::new(1);
        let (x, y, _) = regression_data(&mut rng, 32, 8);
        let mut e = engine(&mut rng, 8, 32, Policy::TopK, 8, true);
        let before = e.evaluate(&x, &y).0;
        for _ in 0..400 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        let after = e.evaluate(&x, &y).0;
        assert!(after < before * 0.05, "before={before} after={after}");
    }

    #[test]
    fn randk_policies_all_run() {
        let mut rng = Rng::new(2);
        let (x, y, _) = regression_data(&mut rng, 24, 6);
        for policy in [
            Policy::RandK,
            Policy::WeightedK,
            Policy::WeightedKReplacement,
        ] {
            let mut e = engine(&mut rng, 6, 24, policy, 6, true);
            for _ in 0..20 {
                let st = e.step(&x, &y, 0.02, &mut rng);
                assert!(st.loss.is_finite(), "{policy:?}");
            }
            assert!(e.w.is_finite(), "{policy:?}");
        }
    }

    #[test]
    fn compact_and_mask_regimes_agree() {
        let mut rng = Rng::new(3);
        let (x, y, _) = regression_data(&mut rng, 20, 5);
        let mk = |compact: bool, rng: &mut Rng| {
            let mut e = engine(rng, 5, 20, Policy::TopK, 5, true);
            e.compact = compact;
            e
        };
        // identical init via fresh seeded rngs
        let mut ra = Rng::new(7);
        let mut rb = Rng::new(7);
        let mut a = mk(true, &mut ra);
        let mut b = mk(false, &mut rb);
        let mut rng_a = Rng::new(11);
        let mut rng_b = Rng::new(11);
        for _ in 0..25 {
            a.step(&x, &y, 0.03, &mut rng_a);
            b.step(&x, &y, 0.03, &mut rng_b);
        }
        assert!(a.w.max_abs_diff(&b.w) < 1e-5);
    }

    #[test]
    fn memory_defers_and_recovers_gradient_mass() {
        let mut rng = Rng::new(4);
        let (x, y, _) = regression_data(&mut rng, 16, 4);
        let mut e = engine(&mut rng, 4, 16, Policy::TopK, 4, true);
        e.step(&x, &y, 0.05, &mut rng);
        // 12 unselected rows must sit in memory
        assert!(!e.memory.is_zero());
        let nz = (0..16)
            .filter(|&m| e.memory.mem_x.row(m).iter().any(|&v| v != 0.0))
            .count();
        assert_eq!(nz, 12);
    }

    #[test]
    fn no_memory_never_accumulates() {
        let mut rng = Rng::new(5);
        let (x, y, _) = regression_data(&mut rng, 16, 4);
        let mut e = engine(&mut rng, 4, 16, Policy::RandK, 4, false);
        for _ in 0..10 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        assert!(e.memory.is_zero());
    }

    #[test]
    fn step_exec_is_bit_identical_to_serial_step() {
        // unit-level smoke check; the full property matrix lives in
        // rust/tests/exec.rs
        let mut rng = Rng::new(9);
        let (x, y, _) = regression_data(&mut rng, 48, 10);
        let exec4 = Executor::new(4);
        let mut serial = engine(&mut Rng::new(21), 10, 48, Policy::TopK, 12, true);
        let mut par = engine(&mut Rng::new(21), 10, 48, Policy::TopK, 12, true);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..15 {
            let a = serial.step(&x, &y, 0.03, &mut r1);
            let b = par.step_exec(&x, &y, 0.03, &mut r2, &exec4);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.wstar_fro.to_bits(), b.wstar_fro.to_bits());
        }
        assert_eq!(serial.w.data(), par.w.data());
        assert_eq!(serial.b, par.b);
        let (l1, a1) = serial.evaluate(&x, &y);
        let (l2, a2) = par.evaluate_exec(&x, &y, &exec4);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(a1, a2);
    }

    #[test]
    fn bias_update_is_exact() {
        let mut rng = Rng::new(6);
        let (x, y, _) = regression_data(&mut rng, 12, 3);
        let mut e = engine(&mut rng, 3, 12, Policy::TopK, 2, true);
        let o = e.forward(&x);
        let (_, g) = LossKind::Mse.loss_and_grad(&o, &y);
        let db_expect: Vec<f32> = g.col_sums().iter().map(|d| 0.05 * d).collect();
        let b0 = e.b.clone();
        e.step(&x, &y, 0.05, &mut rng);
        for i in 0..e.b.len() {
            assert!((e.b[i] - (b0[i] - db_expect[i])).abs() < 1e-6);
        }
    }
}
