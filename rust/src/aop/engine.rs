//! Native single-layer Mem-AOP-GD engine (Algorithm 1, pure Rust).
//!
//! Structured as the same two phases the HLO path executes —
//! `fwd_score` then `apply` — so `rust/tests/native_vs_hlo.rs` can drive
//! both with identical policy decisions and compare states step-by-step.
//! This engine is also the baseline comparator for the criterion-style
//! benches (native CPU vs PJRT-compiled artifacts).

use crate::aop::memory::MemoryState;
use crate::aop::policy::{self, Policy, Selection};
use crate::model::loss::{accuracy, LossKind};
use crate::tensor::rng::Rng;
use crate::tensor::{ops, Matrix};

/// Single dense layer `o = x W + b` trained with Mem-AOP-GD — the paper's
/// experimental model for both tasks (16×1 energy, 784×10 mnist).
pub struct AopEngine {
    pub w: Matrix,
    pub b: Vec<f32>,
    pub loss: LossKind,
    pub memory: MemoryState,
    pub policy: Policy,
    pub k: usize,
    /// Use the compaction-regime kernel (K-row loop) instead of the
    /// mask-regime one. Numerically identical for without-replacement
    /// policies; this is the paper's complexity-reduction execution mode.
    pub compact: bool,
}

/// Outputs of the fwd_score phase (mirrors the HLO artifact's outputs).
pub struct FwdScore {
    pub loss: f32,
    pub xhat: Matrix,
    pub ghat: Matrix,
    pub db: Vec<f32>,
    pub scores: Vec<f32>,
}

/// Per-step diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    /// ||Ŵ*||_F of the applied update.
    pub wstar_fro: f32,
    /// Distinct outer products evaluated.
    pub k_effective: usize,
}

impl AopEngine {
    pub fn new(
        w: Matrix,
        loss: LossKind,
        batch: usize,
        policy: Policy,
        k: usize,
        memory_enabled: bool,
    ) -> Self {
        let (n, p) = w.shape();
        AopEngine {
            b: vec![0.0; p],
            w,
            loss,
            memory: MemoryState::new(batch, n, p, memory_enabled),
            policy,
            k,
            compact: true,
        }
    }

    /// Forward output `x W + b`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        x.matmul(&self.w).add_row_broadcast(&self.b)
    }

    /// Phase 1 (mirrors the `*_fwd_score` artifact): forward, loss,
    /// output-gradient, memory folding, policy scores, exact bias grad.
    pub fn fwd_score(&self, x: &Matrix, y: &Matrix, eta: f32) -> FwdScore {
        let o = self.forward(x);
        let (loss, g) = self.loss.loss_and_grad(&o, y);
        let (xhat, ghat) = self.memory.fold(x, &g, eta);
        let scores = ops::norm_product_scores(&xhat, &ghat);
        let db: Vec<f32> = g.col_sums().iter().map(|d| eta * d).collect();
        FwdScore {
            loss,
            xhat,
            ghat,
            db,
            scores,
        }
    }

    /// Phase 2 (mirrors the `*_apply` artifact): AOP weight update, exact
    /// bias update, memory update.
    pub fn apply(&mut self, fs: &FwdScore, sel: &Selection) -> StepStats {
        let wstar = if self.compact {
            ops::masked_outer_compact(&fs.xhat, &fs.ghat, &sel.compact_pairs())
        } else {
            ops::masked_outer(&fs.xhat, &fs.ghat, &sel.sel_scale)
        };
        let wstar_fro = wstar.frobenius();
        self.w.axpy(-1.0, &wstar);
        for (b, d) in self.b.iter_mut().zip(fs.db.iter()) {
            *b -= d;
        }
        self.memory.update(&fs.xhat, &fs.ghat, &sel.keep);
        StepStats {
            loss: fs.loss,
            wstar_fro,
            k_effective: sel.k_effective(),
        }
    }

    /// Full Algorithm-1 step: fwd_score → out_K → apply.
    pub fn step(&mut self, x: &Matrix, y: &Matrix, eta: f32, rng: &mut Rng) -> StepStats {
        let fs = self.fwd_score(x, y, eta);
        let sel = policy::select(
            self.policy,
            &fs.scores,
            self.k.min(fs.scores.len()),
            self.memory.enabled,
            rng,
        );
        self.apply(&fs, &sel)
    }

    /// Validation loss and accuracy.
    pub fn evaluate(&self, x: &Matrix, y: &Matrix) -> (f32, f32) {
        let o = self.forward(x);
        (self.loss.loss(&o, y), accuracy(&o, y))
    }

    /// Remark-1 step: produce the *raw* AOP gradient estimate (memory
    /// folded with η = 1, so Ŵ* ≈ X^T G itself) and hand it to an
    /// external optimizer (SGD / momentum / Adam) that owns the step
    /// size. With `Optimizer::Sgd` this reduces to [`AopEngine::step`]
    /// at the same lr.
    pub fn step_with_optimizer(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        opt: &crate::aop::optimizer::Optimizer,
        state: &mut crate::aop::optimizer::OptState,
        rng: &mut Rng,
    ) -> StepStats {
        let fs = self.fwd_score(x, y, 1.0);
        let sel = policy::select(
            self.policy,
            &fs.scores,
            self.k.min(fs.scores.len()),
            self.memory.enabled,
            rng,
        );
        let gw = if self.compact {
            ops::masked_outer_compact(&fs.xhat, &fs.ghat, &sel.compact_pairs())
        } else {
            ops::masked_outer(&fs.xhat, &fs.ghat, &sel.sel_scale)
        };
        // fwd_score folded η=1, so db is the raw bias gradient
        state.apply(opt, &mut self.w, &mut self.b, &gw, &fs.db);
        self.memory.update(&fs.xhat, &fs.ghat, &sel.keep);
        StepStats {
            loss: fs.loss,
            wstar_fro: gw.frobenius(),
            k_effective: sel.k_effective(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init;

    fn regression_data(rng: &mut Rng, m: usize, n: usize) -> (Matrix, Matrix, Matrix) {
        // linear teacher with noise
        let teacher = Matrix::from_fn(n, 1, |_, _| rng.normal());
        let x = Matrix::from_fn(m, n, |_, _| rng.normal());
        let y = x.matmul(&teacher).map(|v| v); // noiseless: easy target
        (x, y, teacher)
    }

    fn engine(rng: &mut Rng, n: usize, batch: usize, policy: Policy, k: usize, mem: bool) -> AopEngine {
        AopEngine::new(
            init::glorot_uniform(rng, n, 1),
            LossKind::Mse,
            batch,
            policy,
            k,
            mem,
        )
    }

    #[test]
    fn exact_policy_converges_linear_regression() {
        let mut rng = Rng::new(0);
        let (x, y, _) = regression_data(&mut rng, 32, 8);
        let mut e = engine(&mut rng, 8, 32, Policy::Exact, 32, false);
        let before = e.evaluate(&x, &y).0;
        for _ in 0..300 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        let after = e.evaluate(&x, &y).0;
        assert!(after < before * 1e-2, "before={before} after={after}");
    }

    #[test]
    fn topk_with_memory_converges() {
        let mut rng = Rng::new(1);
        let (x, y, _) = regression_data(&mut rng, 32, 8);
        let mut e = engine(&mut rng, 8, 32, Policy::TopK, 8, true);
        let before = e.evaluate(&x, &y).0;
        for _ in 0..400 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        let after = e.evaluate(&x, &y).0;
        assert!(after < before * 0.05, "before={before} after={after}");
    }

    #[test]
    fn randk_policies_all_run() {
        let mut rng = Rng::new(2);
        let (x, y, _) = regression_data(&mut rng, 24, 6);
        for policy in [
            Policy::RandK,
            Policy::WeightedK,
            Policy::WeightedKReplacement,
        ] {
            let mut e = engine(&mut rng, 6, 24, policy, 6, true);
            for _ in 0..20 {
                let st = e.step(&x, &y, 0.02, &mut rng);
                assert!(st.loss.is_finite(), "{policy:?}");
            }
            assert!(e.w.is_finite(), "{policy:?}");
        }
    }

    #[test]
    fn compact_and_mask_regimes_agree() {
        let mut rng = Rng::new(3);
        let (x, y, _) = regression_data(&mut rng, 20, 5);
        let mk = |compact: bool, rng: &mut Rng| {
            let mut e = engine(rng, 5, 20, Policy::TopK, 5, true);
            e.compact = compact;
            e
        };
        // identical init via fresh seeded rngs
        let mut ra = Rng::new(7);
        let mut rb = Rng::new(7);
        let mut a = mk(true, &mut ra);
        let mut b = mk(false, &mut rb);
        let mut rng_a = Rng::new(11);
        let mut rng_b = Rng::new(11);
        for _ in 0..25 {
            a.step(&x, &y, 0.03, &mut rng_a);
            b.step(&x, &y, 0.03, &mut rng_b);
        }
        assert!(a.w.max_abs_diff(&b.w) < 1e-5);
    }

    #[test]
    fn memory_defers_and_recovers_gradient_mass() {
        let mut rng = Rng::new(4);
        let (x, y, _) = regression_data(&mut rng, 16, 4);
        let mut e = engine(&mut rng, 4, 16, Policy::TopK, 4, true);
        e.step(&x, &y, 0.05, &mut rng);
        // 12 unselected rows must sit in memory
        assert!(!e.memory.is_zero());
        let nz = (0..16)
            .filter(|&m| e.memory.mem_x.row(m).iter().any(|&v| v != 0.0))
            .count();
        assert_eq!(nz, 12);
    }

    #[test]
    fn no_memory_never_accumulates() {
        let mut rng = Rng::new(5);
        let (x, y, _) = regression_data(&mut rng, 16, 4);
        let mut e = engine(&mut rng, 4, 16, Policy::RandK, 4, false);
        for _ in 0..10 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        assert!(e.memory.is_zero());
    }

    #[test]
    fn bias_update_is_exact() {
        let mut rng = Rng::new(6);
        let (x, y, _) = regression_data(&mut rng, 12, 3);
        let mut e = engine(&mut rng, 3, 12, Policy::TopK, 2, true);
        let o = e.forward(&x);
        let (_, g) = LossKind::Mse.loss_and_grad(&o, &y);
        let db_expect: Vec<f32> = g.col_sums().iter().map(|d| 0.05 * d).collect();
        let b0 = e.b.clone();
        e.step(&x, &y, 0.05, &mut rng);
        for i in 0..e.b.len() {
            assert!((e.b[i] - (b0[i] - db_expect[i])).abs() < 1e-6);
        }
    }
}
