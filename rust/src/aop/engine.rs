//! Native single-layer Mem-AOP-GD engine — a thin adapter over the
//! layer-graph training core ([`crate::train`]).
//!
//! `AopEngine` is exactly a 1-layer identity-activation [`Graph`] with a
//! flat `{policy, k, memory}` [`GraphState`]: the paper's experimental
//! model for both tasks (16×1 energy, 784×10 mnist). The actual
//! forward/fold/score/apply math lives *once* in `train::step`; this
//! type only keeps the historical constructor/step/evaluate surface for
//! the benches, the property suite and the single-layer examples.
//!
//! Everything executes through the [`exec`](crate::exec) subsystem: the
//! plain `step`/`evaluate` methods are the `threads = 1` special case
//! (an inline [`Executor::serial`]) of their `_exec` twins, running the
//! very same code path — so results are bit-identical at every thread
//! count.

use crate::aop::memory::MemoryState;
use crate::aop::policy::Policy;
use crate::exec::Executor;
use crate::model::loss::LossKind;
use crate::tensor::rng::Rng;
use crate::tensor::Matrix;
use crate::train::{self, AopLayerConfig, Graph, GraphState, GraphWorkspace, StepOutcome};

/// Single dense layer `o = x W + b` trained with Mem-AOP-GD.
pub struct AopEngine {
    graph: Graph,
    state: GraphState,
    /// Resident step workspace (§Perf pass): steady-state `step`/
    /// `step_exec` calls perform zero heap allocations.
    ws: GraphWorkspace,
    /// Use the compaction-regime kernel (K-row loop) instead of the
    /// mask-regime one. Numerically identical for without-replacement
    /// policies; this is the paper's complexity-reduction execution mode.
    pub compact: bool,
}

/// Per-step diagnostics (single-layer view of [`StepOutcome`]).
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    /// ||Ŵ*||_F of the applied update.
    pub wstar_fro: f32,
    /// Distinct outer products evaluated.
    pub k_effective: usize,
}

impl From<StepOutcome> for StepStats {
    fn from(o: StepOutcome) -> StepStats {
        StepStats {
            loss: o.loss,
            wstar_fro: o.wstar_fro,
            k_effective: o.k_effective,
        }
    }
}

impl AopEngine {
    pub fn new(
        w: Matrix,
        loss: LossKind,
        batch: usize,
        policy: Policy,
        k: usize,
        memory_enabled: bool,
    ) -> Self {
        let graph = Graph::single(w, loss);
        let state = GraphState::from_configs(
            &graph,
            batch,
            &[AopLayerConfig {
                k,
                policy,
                memory: memory_enabled,
            }],
        );
        let ws = GraphWorkspace::new(&graph, batch);
        AopEngine {
            graph,
            state,
            ws,
            compact: true,
        }
    }

    /// The layer's weights.
    pub fn w(&self) -> &Matrix {
        &self.graph.layers[0].w
    }

    /// The layer's bias.
    pub fn b(&self) -> &[f32] {
        &self.graph.layers[0].b
    }

    /// The layer's error-feedback memory.
    pub fn memory(&self) -> &MemoryState {
        &self.state.layers[0].mem
    }

    /// The flat selection config this engine was built with.
    pub fn layer_cfg(&self) -> AopLayerConfig {
        self.state.layers[0].cfg
    }

    /// Forward output `x W + b`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.graph.forward(x)
    }

    /// Full Algorithm-1 step: fwd_score → out_K → apply.
    /// Serial (`threads = 1`) case of [`AopEngine::step_exec`].
    pub fn step(&mut self, x: &Matrix, y: &Matrix, eta: f32, rng: &mut Rng) -> StepStats {
        self.step_exec(x, y, eta, rng, &Executor::serial())
    }

    /// Full data-parallel Algorithm-1 step. The policy decision runs on
    /// the calling thread from the global score vector — selection is
    /// identical at every thread count by construction.
    pub fn step_exec(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        eta: f32,
        rng: &mut Rng,
        exec: &Executor,
    ) -> StepStats {
        train::train_step_ws(
            &mut self.graph,
            &mut self.state,
            x,
            y,
            eta,
            rng,
            exec,
            self.compact,
            &mut self.ws,
        )
        .into()
    }

    /// Validation loss and accuracy.
    /// Serial (`threads = 1`) case of [`AopEngine::evaluate_exec`].
    pub fn evaluate(&self, x: &Matrix, y: &Matrix) -> (f32, f32) {
        self.graph.evaluate(x, y)
    }

    /// Validation, data-parallel (per-shard forward + fixed-order
    /// reductions).
    pub fn evaluate_exec(&self, x: &Matrix, y: &Matrix, exec: &Executor) -> (f32, f32) {
        self.graph.evaluate_exec(x, y, exec)
    }

    /// Remark-1 step: produce the *raw* AOP gradient estimate (memory
    /// folded with η = 1, so Ŵ* ≈ X^T G itself) and hand it to an
    /// external optimizer (SGD / momentum / Adam) that owns the step
    /// size. With `Optimizer::Sgd` this reduces to [`AopEngine::step`]
    /// at the same lr.
    pub fn step_with_optimizer(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        opt: &crate::aop::optimizer::Optimizer,
        ost: &mut crate::aop::optimizer::OptState,
        rng: &mut Rng,
    ) -> StepStats {
        let exec = Executor::serial();
        let (loss, _) = train::fwd_score(&self.graph, &self.state, x, y, 1.0, &exec, &mut self.ws);
        // this path applies through the optimizer, not train::apply —
        // drop the pending fwd marker so the pairing guard stays honest
        self.ws.clear_fwd();
        train::select_layers_ws(&self.state, &mut self.ws, rng);
        let sels = self.ws.take_sels();
        let gw = train::aop_weight_grad_ws(&mut self.ws, 0, &sels[0], self.compact, &exec);
        let layer = &mut self.graph.layers[0];
        // fwd_score folded η=1, so db is the raw bias gradient
        ost.apply(opt, &mut layer.w, &mut layer.b, &gw, self.ws.db(0));
        // the optimizer mutated w out of band — re-derive the cache
        layer.refresh_w_t();
        self.state.layers[0]
            .mem
            .update(self.ws.xhat(0), self.ws.ghat(0), &sels[0].keep);
        let stats = StepStats {
            loss,
            wstar_fro: gw.frobenius(),
            k_effective: sels[0].k_effective(),
        };
        self.ws.put_sels(sels);
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::init;

    fn regression_data(rng: &mut Rng, m: usize, n: usize) -> (Matrix, Matrix, Matrix) {
        // linear teacher with noise
        let teacher = Matrix::from_fn(n, 1, |_, _| rng.normal());
        let x = Matrix::from_fn(m, n, |_, _| rng.normal());
        let y = x.matmul(&teacher).map(|v| v); // noiseless: easy target
        (x, y, teacher)
    }

    fn engine(rng: &mut Rng, n: usize, batch: usize, policy: Policy, k: usize, mem: bool) -> AopEngine {
        AopEngine::new(
            init::glorot_uniform(rng, n, 1),
            LossKind::Mse,
            batch,
            policy,
            k,
            mem,
        )
    }

    #[test]
    fn exact_policy_converges_linear_regression() {
        let mut rng = Rng::new(0);
        let (x, y, _) = regression_data(&mut rng, 32, 8);
        let mut e = engine(&mut rng, 8, 32, Policy::Exact, 32, false);
        let before = e.evaluate(&x, &y).0;
        for _ in 0..300 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        let after = e.evaluate(&x, &y).0;
        assert!(after < before * 1e-2, "before={before} after={after}");
    }

    #[test]
    fn topk_with_memory_converges() {
        let mut rng = Rng::new(1);
        let (x, y, _) = regression_data(&mut rng, 32, 8);
        let mut e = engine(&mut rng, 8, 32, Policy::TopK, 8, true);
        let before = e.evaluate(&x, &y).0;
        for _ in 0..400 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        let after = e.evaluate(&x, &y).0;
        assert!(after < before * 0.05, "before={before} after={after}");
    }

    #[test]
    fn randk_policies_all_run() {
        let mut rng = Rng::new(2);
        let (x, y, _) = regression_data(&mut rng, 24, 6);
        for policy in [
            Policy::RandK,
            Policy::WeightedK,
            Policy::WeightedKReplacement,
        ] {
            let mut e = engine(&mut rng, 6, 24, policy, 6, true);
            for _ in 0..20 {
                let st = e.step(&x, &y, 0.02, &mut rng);
                assert!(st.loss.is_finite(), "{policy:?}");
            }
            assert!(e.w().is_finite(), "{policy:?}");
        }
    }

    #[test]
    fn compact_and_mask_regimes_agree() {
        let mut rng = Rng::new(3);
        let (x, y, _) = regression_data(&mut rng, 20, 5);
        let mk = |compact: bool, rng: &mut Rng| {
            let mut e = engine(rng, 5, 20, Policy::TopK, 5, true);
            e.compact = compact;
            e
        };
        // identical init via fresh seeded rngs
        let mut ra = Rng::new(7);
        let mut rb = Rng::new(7);
        let mut a = mk(true, &mut ra);
        let mut b = mk(false, &mut rb);
        let mut rng_a = Rng::new(11);
        let mut rng_b = Rng::new(11);
        for _ in 0..25 {
            a.step(&x, &y, 0.03, &mut rng_a);
            b.step(&x, &y, 0.03, &mut rng_b);
        }
        assert!(a.w().max_abs_diff(b.w()) < 1e-5);
    }

    #[test]
    fn memory_defers_and_recovers_gradient_mass() {
        let mut rng = Rng::new(4);
        let (x, y, _) = regression_data(&mut rng, 16, 4);
        let mut e = engine(&mut rng, 4, 16, Policy::TopK, 4, true);
        e.step(&x, &y, 0.05, &mut rng);
        // 12 unselected rows must sit in memory
        assert!(!e.memory().is_zero());
        let nz = (0..16)
            .filter(|&m| e.memory().mem_x.row(m).iter().any(|&v| v != 0.0))
            .count();
        assert_eq!(nz, 12);
    }

    #[test]
    fn no_memory_never_accumulates_and_never_allocates() {
        let mut rng = Rng::new(5);
        let (x, y, _) = regression_data(&mut rng, 16, 4);
        let mut e = engine(&mut rng, 4, 16, Policy::RandK, 4, false);
        for _ in 0..10 {
            e.step(&x, &y, 0.05, &mut rng);
        }
        assert!(e.memory().is_zero());
        // disabled memory is the storage-free state, not an M×N zero pair
        assert_eq!(e.memory().mem_x.shape(), (0, 0));
    }

    #[test]
    fn step_exec_is_bit_identical_to_serial_step() {
        // unit-level smoke check; the full property matrix lives in
        // rust/tests/exec.rs
        let mut rng = Rng::new(9);
        let (x, y, _) = regression_data(&mut rng, 48, 10);
        let exec4 = Executor::new(4);
        let mut serial = engine(&mut Rng::new(21), 10, 48, Policy::TopK, 12, true);
        let mut par = engine(&mut Rng::new(21), 10, 48, Policy::TopK, 12, true);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..15 {
            let a = serial.step(&x, &y, 0.03, &mut r1);
            let b = par.step_exec(&x, &y, 0.03, &mut r2, &exec4);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.wstar_fro.to_bits(), b.wstar_fro.to_bits());
        }
        assert_eq!(serial.w().data(), par.w().data());
        assert_eq!(serial.b(), par.b());
        let (l1, a1) = serial.evaluate(&x, &y);
        let (l2, a2) = par.evaluate_exec(&x, &y, &exec4);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(a1, a2);
    }

    #[test]
    fn bias_update_is_exact() {
        let mut rng = Rng::new(6);
        let (x, y, _) = regression_data(&mut rng, 12, 3);
        let mut e = engine(&mut rng, 3, 12, Policy::TopK, 2, true);
        let o = e.forward(&x);
        let (_, g) = LossKind::Mse.loss_and_grad(&o, &y);
        let db_expect: Vec<f32> = g.col_sums().iter().map(|d| 0.05 * d).collect();
        let b0 = e.b().to_vec();
        e.step(&x, &y, 0.05, &mut rng);
        for i in 0..e.b().len() {
            assert!((e.b()[i] - (b0[i] - db_expect[i])).abs() < 1e-6);
        }
    }
}
