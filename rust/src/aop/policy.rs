//! Selection policies (`out_K` of alg. line 5 / Sec. II-B).
//!
//! Given the per-row scores `s_m = ||X̂_(m)|| · ||Ĝ_(m)||`, a policy picks
//! the K outer products to evaluate and emits two vectors consumed by both
//! the native and the HLO apply phase:
//!
//! * `sel_scale[m]` — 0 for unselected rows; for selected rows, 1 for
//!   topK/randK/weightedK-without-replacement (the paper's experiments),
//!   or the unbiased `count/(p_m K)` weight for with-replacement
//!   weightedK (eq. (5));
//! * `keep[m]` — `1 - selected`, masked to all-zero when memory is off.
//!
//! The policy decision lives in the Rust coordinator (Layer 3), which is
//! what lets a single compiled HLO artifact serve every policy and every K.

use crate::tensor::rng::Rng;

/// The `out_K` operator choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Exact back-propagation (all M outer products) — the paper's blue
    /// baseline curves.
    Exact,
    /// K largest `||X̂_(m)|| ||Ĝ_(m)||` scores.
    TopK,
    /// K uniformly random rows, without replacement.
    RandK,
    /// K rows ∝ scores, without replacement (paper's sampling mode).
    WeightedK,
    /// K rows ∝ scores, with replacement + unbiased eq. (5) scaling.
    WeightedKReplacement,
}

impl Policy {
    /// Parse CLI / config names (case-insensitive, surrounding whitespace
    /// ignored, so `TopK` / `  RANDK ` work from hand-typed job specs).
    pub fn parse(s: &str) -> Option<Policy> {
        let t = s.trim().to_ascii_lowercase();
        Some(match t.as_str() {
            "exact" | "baseline" => Policy::Exact,
            "topk" => Policy::TopK,
            "randk" => Policy::RandK,
            "weightedk" => Policy::WeightedK,
            "weightedk-repl" | "weightedk_repl" => Policy::WeightedKReplacement,
            _ => return None,
        })
    }

    /// Every policy, in CLI help / metrics-reporting order.
    pub fn all() -> [Policy; 5] {
        [
            Policy::Exact,
            Policy::TopK,
            Policy::RandK,
            Policy::WeightedK,
            Policy::WeightedKReplacement,
        ]
    }

    /// `Policy::all()` names joined for help text and error messages.
    pub fn names_joined(sep: &str) -> String {
        Policy::all()
            .iter()
            .map(|p| p.name())
            // lint: allow(hot-path-alloc) help/error-text helper, never on the step path
            .collect::<Vec<_>>()
            .join(sep)
    }

    /// Like [`Policy::parse`] but with an actionable error listing the
    /// accepted names — used by the CLI and the serve protocol.
    pub fn parse_or_suggest(s: &str) -> Result<Policy, String> {
        Policy::parse(s).ok_or_else(|| {
            // lint: allow(hot-path-alloc) config-parse error path, runs once per submit
            format!(
                "unknown policy '{s}' (expected one of: {})",
                Policy::names_joined(", ")
            )
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Exact => "exact",
            Policy::TopK => "topk",
            Policy::RandK => "randk",
            Policy::WeightedK => "weightedk",
            Policy::WeightedKReplacement => "weightedk-repl",
        }
    }

    /// All policies the figure harness sweeps (paper's legend order).
    pub fn figure_set() -> [Policy; 3] {
        [Policy::TopK, Policy::WeightedK, Policy::RandK]
    }

    /// Whether the policy uses randomness (determines RNG consumption —
    /// relevant for native/HLO decision parity).
    pub fn is_stochastic(&self) -> bool {
        !matches!(self, Policy::Exact | Policy::TopK)
    }
}

/// Result of one selection decision.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Per-row AOP scale (0 = not computed). Length M.
    pub sel_scale: Vec<f32>,
    /// Per-row memory retention (1 = row goes to memory). Length M.
    pub keep: Vec<f32>,
    /// The selected indices, deduplicated and **sorted ascending** — the
    /// accumulation order of the compaction-regime AOP is part of the
    /// result's float semantics, so it is pinned to row order (matching
    /// the mask regime) rather than left to sampling/partition order.
    pub indices: Vec<usize>,
}

impl Selection {
    /// An empty selection with capacity for `m` rows — the reusable
    /// workspace form; [`select_into`] fills it without reallocating.
    pub fn with_capacity(m: usize) -> Selection {
        Selection {
            sel_scale: Vec::with_capacity(m),
            keep: Vec::with_capacity(m),
            indices: Vec::with_capacity(m),
        }
    }

    /// Compaction-regime pairs (row, scale) for `masked_outer_compact`.
    pub fn compact_pairs(&self) -> Vec<(usize, f32)> {
        self.indices
            .iter()
            .map(|&i| (i, self.sel_scale[i]))
            // lint: allow(hot-path-alloc) analysis/test convenience; the compaction step iterates indices directly
            .collect()
    }

    /// Number of *distinct* outer products evaluated.
    pub fn k_effective(&self) -> usize {
        self.indices.len()
    }
}

/// Reusable scratch for [`select_into`]: every temporary the policies
/// need (candidate indices, Gumbel keys, the sampling CDF, draw counts)
/// lives here so steady-state selection performs zero heap allocations.
#[derive(Debug, Default)]
pub struct SelectScratch {
    idx: Vec<usize>,
    keys: Vec<(f64, usize)>,
    cdf: Vec<f64>,
    draws: Vec<usize>,
    counts: Vec<u32>,
}

impl SelectScratch {
    pub fn new() -> SelectScratch {
        SelectScratch::default()
    }

    /// Scratch pre-sized for batches of `m` rows. Every buffer a policy
    /// can touch grows to at most `m` entries (`draws` holds k ≤ m
    /// samples; resolved K schedules clamp to `[1, batch]`), so a
    /// workspace built with this never allocates during selection — even
    /// when an annealing schedule changes k mid-run.
    pub fn with_capacity(m: usize) -> SelectScratch {
        SelectScratch {
            idx: Vec::with_capacity(m),
            keys: Vec::with_capacity(m),
            cdf: Vec::with_capacity(m),
            draws: Vec::with_capacity(m),
            counts: Vec::with_capacity(m),
        }
    }
}

/// The deterministic exact-BP selection: every row, unit scale, nothing
/// deferred. Needs no scores and no RNG — the exact-SGD path calls this
/// directly instead of threading a dummy generator through [`select`].
pub fn select_exact(m: usize) -> Selection {
    let mut sel = Selection::with_capacity(m);
    select_exact_into(m, &mut sel);
    sel
}

/// [`select_exact`] into a reusable [`Selection`] (no allocation at
/// capacity).
pub fn select_exact_into(m: usize, sel: &mut Selection) {
    sel.sel_scale.clear();
    sel.sel_scale.resize(m, 1.0);
    sel.keep.clear();
    sel.keep.resize(m, 0.0);
    sel.indices.clear();
    sel.indices.extend(0..m);
}

/// Apply `policy` to `scores`, selecting `k` of `m = scores.len()` rows.
///
/// `memory` toggles the error-feedback retention of unselected rows
/// (continuous vs dashed curves in Figs. 2-3). `rng` is consumed only by
/// stochastic policies.
pub fn select(
    policy: Policy,
    scores: &[f32],
    k: usize,
    memory: bool,
    rng: &mut Rng,
) -> Selection {
    let m = scores.len();
    let mut sel = Selection::with_capacity(m);
    let mut scratch = SelectScratch::new();
    select_into(policy, scores, k, memory, rng, &mut scratch, &mut sel);
    sel
}

/// [`select`] into a reusable [`Selection`] + [`SelectScratch`] — the
/// identical decision (same RNG consumption, same indices/scales/keep)
/// with zero heap allocations once the buffers have seen a batch of this
/// size. This is the form the workspace-resident training step calls.
pub fn select_into(
    policy: Policy,
    scores: &[f32],
    k: usize,
    memory: bool,
    rng: &mut Rng,
    scratch: &mut SelectScratch,
    sel: &mut Selection,
) {
    let m = scores.len();
    assert!(k <= m, "k={k} > m={m}");
    if policy == Policy::Exact {
        select_exact_into(m, sel);
        return;
    }
    sel.sel_scale.clear();
    sel.sel_scale.resize(m, 0.0);
    sel.indices.clear();
    match policy {
        Policy::Exact => unreachable!("handled above"),
        Policy::TopK => top_k_indices_into(scores, k, &mut scratch.idx, &mut sel.indices),
        Policy::RandK => {
            rng.sample_without_replacement_into(m, k, &mut scratch.idx, &mut sel.indices)
        }
        Policy::WeightedK => rng.weighted_sample_without_replacement_into(
            scores,
            k,
            &mut scratch.keys,
            &mut sel.indices,
        ),
        Policy::WeightedKReplacement => {
            let total: f64 = scores.iter().map(|&s| s.max(0.0) as f64).sum();
            rng.weighted_sample_with_replacement_into(
                scores,
                k,
                &mut scratch.cdf,
                &mut scratch.draws,
            );
            scratch.counts.clear();
            scratch.counts.resize(m, 0);
            for &i in &scratch.draws {
                scratch.counts[i] += 1;
            }
            for (i, &c) in scratch.counts.iter().enumerate() {
                if c > 0 {
                    let p = (scores[i].max(0.0) as f64 / total).max(1e-30);
                    sel.sel_scale[i] = (c as f64 / (p * k as f64)) as f32;
                    sel.indices.push(i);
                }
            }
            // scales already set; mark keep and return
            keep_vector_into(&sel.indices, m, memory, policy, &mut sel.keep);
            return;
        }
    };
    // pin the accumulation order (see `Selection::indices`); which rows
    // were drawn is already decided, so this never changes the sample
    sel.indices.sort_unstable();
    for &i in &sel.indices {
        sel.sel_scale[i] = 1.0;
    }
    keep_vector_into(&sel.indices, m, memory, policy, &mut sel.keep);
}

fn keep_vector_into(indices: &[usize], m: usize, memory: bool, policy: Policy, keep: &mut Vec<f32>) {
    keep.clear();
    if !memory || policy == Policy::Exact {
        keep.resize(m, 0.0);
        return;
    }
    keep.resize(m, 1.0);
    for &i in indices {
        keep[i] = 0.0;
    }
}

/// Indices of the K largest scores, **sorted ascending**. Uses
/// `select_nth_unstable` (O(m) on average) instead of a full sort — this
/// sits on the per-step hot path.
///
/// Determinism contract: ties are broken by row index (lower index
/// wins), so the selected *set* is a pure function of the scores — not
/// of the partition's internal order, which `select_nth_unstable` leaves
/// unspecified across std versions and platforms. The returned order is
/// then pinned to ascending row index so downstream accumulation (and
/// per-shard filtering in `exec`) is reproducible across shard
/// boundaries and platforms.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    // lint: allow(hot-path-alloc) allocating wrapper; the step path uses top_k_indices_into with workspace buffers
    let (mut scratch, mut out) = (Vec::new(), Vec::new());
    top_k_indices_into(scores, k, &mut scratch, &mut out);
    out
}

/// [`top_k_indices`] into reusable buffers — same selected set, same
/// ascending order, no allocation at capacity (`select_nth_unstable` and
/// `sort_unstable` are both in-place).
pub fn top_k_indices_into(scores: &[f32], k: usize, scratch: &mut Vec<usize>, out: &mut Vec<usize>) {
    let m = scores.len();
    out.clear();
    if k == 0 {
        return;
    }
    if k >= m {
        out.extend(0..m);
        return;
    }
    scratch.clear();
    scratch.extend(0..m);
    scratch.select_nth_unstable_by(k - 1, |&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            // tie-break on index: total order ⇒ the selected set is unique
            .then(a.cmp(&b))
    });
    out.extend_from_slice(&scratch[..k]);
    out.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn parse_roundtrip() {
        for p in Policy::all() {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
        assert_eq!(Policy::parse("baseline"), Some(Policy::Exact));
    }

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(Policy::parse("TopK"), Some(Policy::TopK));
        assert_eq!(Policy::parse(" RANDK "), Some(Policy::RandK));
        assert_eq!(Policy::parse("WeightedK-Repl"), Some(Policy::WeightedKReplacement));
        assert_eq!(Policy::parse("Baseline"), Some(Policy::Exact));
    }

    #[test]
    fn suggestions_list_all_names() {
        let err = Policy::parse_or_suggest("bogus").unwrap_err();
        for p in Policy::all() {
            assert!(err.contains(p.name()), "{err}");
        }
        assert!(err.contains("bogus"));
        assert_eq!(Policy::parse_or_suggest("topk"), Ok(Policy::TopK));
    }

    #[test]
    fn top_k_selects_largest() {
        let scores = [0.1, 5.0, 0.2, 3.0, 0.05, 4.0];
        let mut idx = top_k_indices(&scores, 3);
        idx.sort_unstable();
        assert_eq!(idx, vec![1, 3, 5]);
    }

    #[test]
    fn top_k_edge_cases() {
        assert!(top_k_indices(&[1.0, 2.0], 0).is_empty());
        assert_eq!(top_k_indices(&[1.0, 2.0], 2).len(), 2);
        assert_eq!(top_k_indices(&[1.0, 2.0], 5).len(), 2);
    }

    #[test]
    fn top_k_deterministic_under_ties() {
        let scores = vec![1.0f32; 10];
        let mut a = top_k_indices(&scores, 4);
        let mut b = top_k_indices(&scores, 4);
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        assert_eq!(a, vec![0, 1, 2, 3]); // index tie-break
    }

    #[test]
    fn top_k_returns_ascending_indices() {
        let scores = [0.1, 5.0, 0.2, 3.0, 0.05, 4.0];
        assert_eq!(top_k_indices(&scores, 3), vec![1, 3, 5]);
        // ties spanning shard boundaries resolve to the lowest row indices
        let tied = vec![2.0f32; 40];
        assert_eq!(top_k_indices(&tied, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn selection_indices_are_sorted_for_every_policy() {
        let scores: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 + 0.5).collect();
        let mut r = rng();
        for policy in Policy::all() {
            let s = select(policy, &scores, 10, true, &mut r);
            for w in s.indices.windows(2) {
                assert!(w[0] < w[1], "{policy:?}: indices not ascending");
            }
        }
    }

    #[test]
    fn exact_selects_all_and_keeps_nothing() {
        let s = select(Policy::Exact, &[1.0, 2.0, 3.0], 2, true, &mut rng());
        assert_eq!(s.indices.len(), 3);
        assert!(s.sel_scale.iter().all(|&v| v == 1.0));
        assert!(s.keep.iter().all(|&v| v == 0.0));
        // select(Exact) is exactly select_exact — no RNG, no scores read
        let direct = select_exact(3);
        assert_eq!(direct.indices, s.indices);
        assert_eq!(direct.sel_scale, s.sel_scale);
        assert_eq!(direct.keep, s.keep);
        assert_eq!(direct.k_effective(), 3);
    }

    #[test]
    fn selection_partitions_rows_with_memory() {
        let scores: Vec<f32> = (0..20).map(|i| (i as f32).sin().abs() + 0.1).collect();
        for policy in [Policy::TopK, Policy::RandK, Policy::WeightedK] {
            let s = select(policy, &scores, 7, true, &mut rng());
            assert_eq!(s.k_effective(), 7, "{policy:?}");
            for m in 0..20 {
                let selected = s.sel_scale[m] != 0.0;
                let kept = s.keep[m] != 0.0;
                assert!(selected ^ kept, "{policy:?} row {m}: sel xor keep violated");
            }
        }
    }

    #[test]
    fn no_memory_keeps_nothing() {
        let scores = vec![1.0f32; 10];
        let s = select(Policy::TopK, &scores, 3, false, &mut rng());
        assert!(s.keep.iter().all(|&v| v == 0.0));
        assert_eq!(s.k_effective(), 3);
    }

    #[test]
    fn weighted_with_replacement_scales_unbiased() {
        // mean of sel_scale over many draws ≈ 1 for each row
        let scores = [1.0f32, 2.0, 3.0, 4.0];
        let mut r = rng();
        let mut acc = [0.0f64; 4];
        let trials = 20000;
        for _ in 0..trials {
            let s = select(Policy::WeightedKReplacement, &scores, 2, false, &mut r);
            for i in 0..4 {
                acc[i] += s.sel_scale[i] as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let mean = a / trials as f64;
            assert!((mean - 1.0).abs() < 0.1, "row {i}: mean scale {mean}");
        }
    }

    #[test]
    fn compact_pairs_match_scales() {
        let scores = [5.0f32, 1.0, 4.0, 2.0];
        let s = select(Policy::TopK, &scores, 2, true, &mut rng());
        let pairs = s.compact_pairs();
        assert_eq!(pairs.len(), 2);
        for (i, sc) in pairs {
            assert_eq!(sc, s.sel_scale[i]);
            assert!(sc == 1.0);
        }
    }

    #[test]
    fn stochastic_flag() {
        assert!(!Policy::Exact.is_stochastic());
        assert!(!Policy::TopK.is_stochastic());
        assert!(Policy::RandK.is_stochastic());
        assert!(Policy::WeightedK.is_stochastic());
    }
}
