//! Error-feedback memory state (`m^X`, `m^G` of Algorithm 1).
//!
//! The memories store the *rows of X̂/Ĝ that were not selected* at the
//! previous step (lines 8-9) and are folded back in at lines 3-4:
//!
//!   X̂_t = m^X_t + sqrt(η_t) X_t,   Ĝ_t = m^G_t + sqrt(η_t) G_t.
//!
//! Invariant maintained (and property-tested): after `update`, a row of
//! memory is either exactly 0 (selected, consumed by the weight update) or
//! exactly the corresponding row of X̂/Ĝ (unselected, deferred).

use crate::tensor::{ops, Matrix};

/// Per-layer error-feedback state.
#[derive(Debug, Clone)]
pub struct MemoryState {
    pub mem_x: Matrix,
    pub mem_g: Matrix,
    /// When false this is the "without memory" ablation (dashed curves in
    /// Figs. 2-3): the state stays identically zero.
    pub enabled: bool,
}

impl MemoryState {
    /// Fresh zero state for a batch of `m` rows, `n` input features and
    /// `p` outputs.
    pub fn new(m: usize, n: usize, p: usize, enabled: bool) -> Self {
        MemoryState {
            mem_x: Matrix::zeros(m, n),
            mem_g: Matrix::zeros(m, p),
            enabled,
        }
    }

    /// A permanently-off memory holding **no storage** (0×0 matrices):
    /// the "without memory" ablation and the exact-SGD path never
    /// allocate the M×N / M×P state they would never read.
    pub fn disabled() -> Self {
        MemoryState {
            mem_x: Matrix::zeros(0, 0),
            mem_g: Matrix::zeros(0, 0),
            enabled: false,
        }
    }

    /// Lines 3-4: fold the memory into the fresh batch,
    /// returning `(X̂, Ĝ)`.
    pub fn fold(&self, x: &Matrix, g: &Matrix, eta: f32) -> (Matrix, Matrix) {
        let se = eta.sqrt();
        let mut xhat = x.scale(se);
        xhat.axpy(1.0, &self.mem_x);
        let mut ghat = g.scale(se);
        ghat.axpy(1.0, &self.mem_g);
        (xhat, ghat)
    }

    /// Lines 8-9: retain the unselected rows (`keep[m] = 1`) of X̂/Ĝ.
    /// A disabled memory ignores the keep vector and stays zero.
    pub fn update(&mut self, xhat: &Matrix, ghat: &Matrix, keep: &[f32]) {
        if !self.enabled {
            return; // stays zero
        }
        self.mem_x = ops::row_scale(xhat, keep);
        self.mem_g = ops::row_scale(ghat, keep);
    }

    /// Reset to zero (e.g. between experiments).
    pub fn reset(&mut self) {
        self.mem_x = Matrix::zeros(self.mem_x.rows(), self.mem_x.cols());
        self.mem_g = Matrix::zeros(self.mem_g.rows(), self.mem_g.cols());
    }

    /// Squared Frobenius mass of the deferred state — the summable
    /// per-layer partial behind [`MemoryState::deferred_mass`] (the
    /// layer-graph core sums these across layers before one final sqrt).
    pub fn deferred_sq(&self) -> f32 {
        self.mem_x.frobenius().powi(2) + self.mem_g.frobenius().powi(2)
    }

    /// Frobenius norm of the deferred gradient mass (diagnostic; the
    /// metrics sink logs this as `mem_fro`).
    pub fn deferred_mass(&self) -> f32 {
        self.deferred_sq().sqrt()
    }

    pub fn is_zero(&self) -> bool {
        self.mem_x.data().iter().all(|&v| v == 0.0)
            && self.mem_g.data().iter().all(|&v| v == 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn randm(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn fresh_state_is_zero() {
        let ms = MemoryState::new(8, 4, 2, true);
        assert!(ms.is_zero());
        assert_eq!(ms.deferred_mass(), 0.0);
    }

    #[test]
    fn fold_lines_3_4() {
        let mut rng = Rng::new(0);
        let mut ms = MemoryState::new(6, 3, 2, true);
        ms.mem_x = randm(&mut rng, 6, 3);
        ms.mem_g = randm(&mut rng, 6, 2);
        let x = randm(&mut rng, 6, 3);
        let g = randm(&mut rng, 6, 2);
        let eta = 0.04f32;
        let (xhat, ghat) = ms.fold(&x, &g, eta);
        let expect_x = ms.mem_x.add(&x.scale(eta.sqrt()));
        let expect_g = ms.mem_g.add(&g.scale(eta.sqrt()));
        assert!(xhat.max_abs_diff(&expect_x) < 1e-6);
        assert!(ghat.max_abs_diff(&expect_g) < 1e-6);
    }

    #[test]
    fn update_lines_8_9_partitions_rows() {
        let mut rng = Rng::new(1);
        let mut ms = MemoryState::new(10, 4, 3, true);
        let xhat = randm(&mut rng, 10, 4);
        let ghat = randm(&mut rng, 10, 3);
        let keep: Vec<f32> = (0..10).map(|i| (i % 2) as f32).collect();
        ms.update(&xhat, &ghat, &keep);
        for m in 0..10 {
            if keep[m] == 1.0 {
                assert_eq!(ms.mem_x.row(m), xhat.row(m));
                assert_eq!(ms.mem_g.row(m), ghat.row(m));
            } else {
                assert!(ms.mem_x.row(m).iter().all(|&v| v == 0.0));
                assert!(ms.mem_g.row(m).iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn disabled_constructor_holds_no_storage() {
        let ms = MemoryState::disabled();
        assert!(!ms.enabled);
        assert!(ms.is_zero());
        assert_eq!(ms.mem_x.shape(), (0, 0));
        assert_eq!(ms.deferred_mass(), 0.0);
    }

    #[test]
    fn disabled_memory_stays_zero() {
        let mut rng = Rng::new(2);
        let mut ms = MemoryState::new(5, 3, 1, false);
        let xhat = randm(&mut rng, 5, 3);
        let ghat = randm(&mut rng, 5, 1);
        ms.update(&xhat, &ghat, &vec![1.0; 5]);
        assert!(ms.is_zero());
    }

    #[test]
    fn reset_clears() {
        let mut rng = Rng::new(3);
        let mut ms = MemoryState::new(4, 2, 2, true);
        ms.update(
            &randm(&mut rng, 4, 2),
            &randm(&mut rng, 4, 2),
            &vec![1.0; 4],
        );
        assert!(!ms.is_zero());
        ms.reset();
        assert!(ms.is_zero());
    }

    #[test]
    fn eq7_expansion_identity() {
        // At t=2 with full selection, the applied gradient decomposes into
        // the fresh term plus the three memory cross terms of eq. (7).
        let mut rng = Rng::new(4);
        let (m, n, p) = (12, 5, 3);
        let eta = 1.0f32; // paper sets eta_t = 1 in the expansion
        let mut ms = MemoryState::new(m, n, p, true);

        // t=1: select half the rows, defer the rest
        let x1 = randm(&mut rng, m, n);
        let g1 = randm(&mut rng, m, p);
        let (xh1, gh1) = ms.fold(&x1, &g1, eta);
        let keep: Vec<f32> = (0..m).map(|i| (i < m / 2) as u32 as f32).collect();
        ms.update(&xh1, &gh1, &keep);

        // t=2: full selection ⇒ Ŵ*_2 = (m^X + X_2)^T (m^G + G_2)
        let x2 = randm(&mut rng, m, n);
        let g2 = randm(&mut rng, m, p);
        let (xh2, gh2) = ms.fold(&x2, &g2, eta);
        let w_full = ops::matmul_tn(&xh2, &gh2);

        let t_fresh = ops::matmul_tn(&x2, &g2);
        let t_mem = ops::matmul_tn(&ms.mem_x, &ms.mem_g);
        let t_cross1 = ops::matmul_tn(&ms.mem_x, &g2);
        let t_cross2 = ops::matmul_tn(&x2, &ms.mem_g);
        let sum = t_fresh.add(&t_mem).add(&t_cross1).add(&t_cross2);
        assert!(w_full.max_abs_diff(&sum) < 1e-4);
    }
}
