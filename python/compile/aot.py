"""AOT compiler: lower every Layer-2 graph to HLO *text* + manifest.json.

Run once by ``make artifacts``; the Rust binary is self-contained afterwards.

HLO text (NOT ``lowered.compiler_ir(...).serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

The manifest records, for every artifact, the positional input and output
specs (name/shape/dtype) so the Rust runtime can validate literals before
execution and size its buffers without parsing HLO.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, name):
    return {"name": name, "shape": list(shape), "dtype": "f32"}


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_artifact(fn, in_specs, out_names, out_dir, name):
    """Lower ``fn`` against ``in_specs`` and write ``<name>.hlo.txt``.

    Returns the manifest entry for the artifact.
    """
    args = [f32(s["shape"]) for s in in_specs]
    # keep_unused: variants that ignore e.g. their noise inputs must still
    # expose them positionally — the Rust runtime feeds every manifest input
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # output shapes from the jax lowering itself (authoritative)
    out_avals = lowered.out_info
    flat, _ = jax.tree_util.tree_flatten(out_avals)
    outs = [spec(a.shape, n) for a, n in zip(flat, out_names)]
    assert len(flat) == len(out_names), (name, len(flat), len(out_names))
    return {
        "file": fname,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "inputs": in_specs,
        "outputs": outs,
    }


def task_artifacts(task, out_dir):
    cfg = model.TASKS[task]
    m, n, p = cfg["batch"], cfg["n_in"], cfg["n_out"]
    arts = {}

    arts[f"{task}_fwd_score"] = lower_artifact(
        model.fwd_score(task),
        [
            spec((m, n), "x"),
            spec((m, p), "y"),
            spec((n, p), "w"),
            spec((p,), "b"),
            spec((m, n), "mem_x"),
            spec((m, p), "mem_g"),
            spec((), "eta"),
        ],
        ["loss", "xhat", "ghat", "db", "scores"],
        out_dir,
        f"{task}_fwd_score",
    )
    arts[f"{task}_apply"] = lower_artifact(
        model.apply_update(task),
        [
            spec((m, n), "xhat"),
            spec((m, p), "ghat"),
            spec((n, p), "w"),
            spec((p,), "b"),
            spec((p,), "db"),
            spec((m,), "sel_scale"),
            spec((m,), "keep"),
        ],
        ["w_new", "b_new", "mem_x_new", "mem_g_new", "wstar_fro"],
        out_dir,
        f"{task}_apply",
    )
    # fused single-dispatch deployment step (topK + memory, the paper's
    # strongest configuration) — §Perf dispatch-count ablation
    k_fused = {"energy": 18, "mnist": 32}[task]
    arts[f"{task}_fused_topk_mem"] = lower_artifact(
        model.fused_step(task, "topk", True, k_fused),
        [
            spec((m, n), "x"),
            spec((m, p), "y"),
            spec((n, p), "w"),
            spec((p,), "b"),
            spec((m, n), "mem_x"),
            spec((m, p), "mem_g"),
            spec((m,), "noise"),
            spec((), "eta"),
        ],
        ["loss", "w_new", "b_new", "mem_x_new", "mem_g_new"],
        out_dir,
        f"{task}_fused_topk_mem",
    )
    eb = cfg["eval_batch"]
    arts[f"{task}_eval"] = lower_artifact(
        model.evaluate(task),
        [spec((eb, n), "x"), spec((eb, p), "y"), spec((n, p), "w"), spec((p,), "b")],
        ["loss", "acc"],
        out_dir,
        f"{task}_eval",
    )
    return arts


def mlp_artifacts(out_dir):
    arts = {}
    variants = [
        ("mlp_exact", "exact", False),
        ("mlp_topk_mem", "topk", True),
        ("mlp_topk_nomem", "topk", False),
        ("mlp_randk_mem", "randk", True),
        ("mlp_weightedk_mem", "weightedk", True),
    ]
    for name, policy, memory in variants:
        fn, layers, batch, nl = model.mlp_train_step(policy, memory)
        ins = [spec((batch, layers[0]), "x"), spec((batch, layers[-1]), "y")]
        ins += [spec((layers[i], layers[i + 1]), f"w{i}") for i in range(nl)]
        ins += [spec((layers[i + 1],), f"b{i}") for i in range(nl)]
        ins += [spec((batch, layers[i]), f"mx{i}") for i in range(nl)]
        ins += [spec((batch, layers[i + 1]), f"mg{i}") for i in range(nl)]
        ins += [spec((batch,), f"noise{i}") for i in range(nl)]
        ins += [spec((), "eta")]
        outs = ["loss", "acc"]
        outs += [f"w{i}_new" for i in range(nl)]
        outs += [f"b{i}_new" for i in range(nl)]
        outs += [f"mx{i}_new" for i in range(nl)]
        outs += [f"mg{i}_new" for i in range(nl)]
        arts[name] = lower_artifact(fn, ins, outs, out_dir, name)

    fn, layers, batch, nl = model.mlp_eval()
    ins = [spec((batch, layers[0]), "x"), spec((batch, layers[-1]), "y")]
    ins += [spec((layers[i], layers[i + 1]), f"w{i}") for i in range(nl)]
    ins += [spec((layers[i + 1],), f"b{i}") for i in range(nl)]
    arts["mlp_eval"] = lower_artifact(fn, ins, ["loss", "acc"], out_dir, "mlp_eval")
    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts go to its directory")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    arts = {}
    for task in model.TASKS:
        arts.update(task_artifacts(task, out_dir))
        print(f"lowered task '{task}' ({len(arts)} artifacts so far)")
    arts.update(mlp_artifacts(out_dir))
    print(f"lowered mlp variants ({len(arts)} artifacts total)")

    manifest = {
        "version": 1,
        "tasks": model.TASKS,
        "mlp": {
            "layers": model.MLP_LAYERS,
            "batch": model.MLP_BATCH,
            "k": model.MLP_K,
        },
        "artifacts": arts,
    }
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(arts)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
