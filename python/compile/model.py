"""Layer-2 JAX compute graphs for Mem-AOP-GD (build-time only).

Every public function here is lowered once by ``aot.py`` into an HLO-text
artifact executed from the Rust coordinator; Python never runs on the
training path.

Two-phase split (DESIGN.md §2): the per-task train step is split into

  ``*_fwd_score``  forward + loss + output-gradient + memory folding +
                   selection scores, and
  ``*_apply``      Pallas-AOP weight update + memory update,

with the *selection policy itself* (topK / randK / weightedK, any K, with or
without memory) living in the Rust coordinator between the two phases. One
artifact pair therefore serves every policy and every K at runtime.

A monolithic multi-layer MLP train step (selection baked in-graph) is also
provided for the end-to-end example.

Conventions:
  * all tensors float32;
  * batch rows are the outer-product index m in eq. (3);
  * the learning rate enters as ``sqrt(eta)`` on both X and G (alg. lines
    3-4) so the weight update is simply ``W - Ŵ*`` (line 7);
  * the bias gradient is exact (the paper approximates only eq. (2b)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.aop_outer import aop_outer
from compile.kernels.memupd import row_scale
from compile.kernels.scores import scores as scores_kernel

# ---------------------------------------------------------------------------
# task definitions (Tab. I)
# ---------------------------------------------------------------------------

#: (batch M, input N, output P) per task — Tab. I of the paper.
#: ``eval_batch`` sizes the `*_eval` artifact: the whole 192-row validation
#: split for energy; 64-row chunks (drop-tail) for mnist.
TASKS = {
    "energy": dict(batch=144, n_in=16, n_out=1, loss="mse", eval_batch=192),
    "mnist": dict(batch=64, n_in=784, n_out=10, loss="cce", eval_batch=64),
}

#: End-to-end MLP used by ``examples/e2e_train.rs`` (extension beyond the
#: paper's single-layer models): 784-1024-1024-10 ≈ 1.9M parameters.
MLP_LAYERS = [784, 1024, 1024, 10]
MLP_BATCH = 128
MLP_K = 32  # outer products kept per layer (M = MLP_BATCH)


# ---------------------------------------------------------------------------
# losses and output gradients
# ---------------------------------------------------------------------------


def _mse(o, y):
    """Mean-squared error and its gradient w.r.t. o."""
    b = o.shape[0] * o.shape[1]
    loss = jnp.mean((o - y) ** 2)
    g = 2.0 * (o - y) / b
    return loss, g


def _softmax_cce(o, y):
    """Categorical cross-entropy over softmax(o) and its gradient w.r.t. o."""
    logp = jax.nn.log_softmax(o, axis=1)
    loss = -jnp.mean(jnp.sum(y * logp, axis=1))
    g = (jax.nn.softmax(o, axis=1) - y) / o.shape[0]
    return loss, g


def _loss_and_grad(kind, o, y):
    return _mse(o, y) if kind == "mse" else _softmax_cce(o, y)


# ---------------------------------------------------------------------------
# two-phase single-dense-layer graphs (the paper's models)
# ---------------------------------------------------------------------------


def fwd_score(task: str):
    """Phase 1: forward, loss, memory folding, policy scores.

    Signature (positional, fixed order — mirrored in the manifest):
      (x, y, w, b, mem_x, mem_g, eta) ->
      (loss, xhat, ghat, db, scores)
    """
    cfg = TASKS[task]

    def fn(x, y, w, b, mem_x, mem_g, eta):
        o = x @ w + b
        loss, g = _loss_and_grad(cfg["loss"], o, y)
        se = jnp.sqrt(eta)
        xhat = mem_x + se * x
        ghat = mem_g + se * g
        s = scores_kernel(xhat, ghat)
        db = eta * jnp.sum(g, axis=0)
        return loss, xhat, ghat, db, s

    return fn


def apply_update(task: str):
    """Phase 2: Pallas-AOP weight update + exact bias + memory update.

    Signature:
      (xhat, ghat, w, b, db, sel_scale, keep) ->
      (w_new, b_new, mem_x_new, mem_g_new, wstar_fro)

    ``sel_scale[m]`` is 0 for unselected rows and the policy weight for
    selected ones; ``keep[m]`` is 1 for rows retained in memory (0 for the
    no-memory variant and for selected rows). ``wstar_fro`` (||Ŵ*||_F) is a
    free diagnostic for the metrics sink.
    """
    del task  # shapes are baked from the tracer args; math is task-agnostic

    def fn(xhat, ghat, w, b, db, sel_scale, keep):
        wstar = aop_outer(xhat, ghat, sel_scale)
        w_new = w - wstar
        b_new = b - db
        mem_x_new = row_scale(xhat, keep)
        mem_g_new = row_scale(ghat, keep)
        wstar_fro = jnp.sqrt(jnp.sum(wstar * wstar))
        return w_new, b_new, mem_x_new, mem_g_new, wstar_fro

    return fn


def evaluate(task: str):
    """Validation graph: (x, y, w, b) -> (loss, accuracy).

    Accuracy is argmax agreement (meaningful for mnist; for the regression
    task it degenerates to 1.0 and is ignored by the coordinator).
    """
    cfg = TASKS[task]

    def fn(x, y, w, b):
        o = x @ w + b
        loss, _ = _loss_and_grad(cfg["loss"], o, y)
        acc = jnp.mean(
            (jnp.argmax(o, axis=1) == jnp.argmax(y, axis=1)).astype(jnp.float32)
        )
        return loss, acc

    return fn


# ---------------------------------------------------------------------------
# in-graph selection (for the monolithic MLP step)
# ---------------------------------------------------------------------------


def _select_mask(policy: str, s, noise, k: int):
    """Build the 0/1 selection mask for one layer, in-graph.

    topK      — K largest scores (Sec. II-B).
    randK     — K uniform rows: top-K of the uniform noise.
    weightedK — without-replacement sampling ∝ scores via the Gumbel-top-k
                trick: keys = log s + Gumbel(noise).
    exact     — all rows.

    NOTE: implemented with ``lax.sort`` (+ index tie-break) rather than
    ``lax.top_k`` — the xla_extension 0.5.1 HLO parser the Rust runtime
    links against predates the dedicated `topk` HLO op, while `sort` (with
    a multi-operand comparator) round-trips fine.
    """
    m = s.shape[0]
    if policy == "exact":
        return jnp.ones((m,), jnp.float32)
    if policy == "topk":
        keys = s
    elif policy == "randk":
        keys = noise
    elif policy == "weightedk":
        gumbel = -jnp.log(-jnp.log(noise + 1e-12) + 1e-12)
        keys = jnp.log(s + 1e-12) + gumbel
    else:  # pragma: no cover - guarded by aot.py
        raise ValueError(policy)
    iota = jnp.arange(m, dtype=jnp.int32)
    # ascending sort of -keys == descending sort of keys; iota rides along
    _, perm = jax.lax.sort((-keys, iota), dimension=0, num_keys=1)
    idx = perm[:k]
    return jnp.zeros((m,), jnp.float32).at[idx].set(1.0)


# ---------------------------------------------------------------------------
# fused single-dispatch train step (deployment-mode ablation, §Perf)
# ---------------------------------------------------------------------------


def fused_step(task: str, policy: str, memory: bool, k: int):
    """One-dispatch Mem-AOP-GD step with the selection baked in-graph.

    The two-phase split (fwd_score → Rust policy → apply) costs two PJRT
    dispatches and a host round-trip of X̂/Ĝ per step; this fused variant
    trades the runtime policy/K flexibility for a single dispatch — the
    deployment configuration once a policy is chosen. Semantics match the
    two-phase path exactly for deterministic policies (topK / exact).

    Signature:
      (x, y, w, b, mem_x, mem_g, noise, eta) ->
      (loss, w_new, b_new, mem_x_new, mem_g_new)
    """
    cfg = TASKS[task]

    def fn(x, y, w, b, mem_x, mem_g, noise, eta):
        o = x @ w + b
        loss, g = _loss_and_grad(cfg["loss"], o, y)
        se = jnp.sqrt(eta)
        xhat = mem_x + se * x
        ghat = mem_g + se * g
        s = scores_kernel(xhat, ghat)
        mask = _select_mask(policy, s, noise, k)
        keep = (1.0 - mask) if memory else jnp.zeros_like(mask)
        wstar = aop_outer(xhat, ghat, mask)
        w_new = w - wstar
        b_new = b - eta * jnp.sum(g, axis=0)
        return loss, w_new, b_new, row_scale(xhat, keep), row_scale(ghat, keep)

    return fn


# ---------------------------------------------------------------------------
# monolithic multi-layer MLP train step (e2e example / extension)
# ---------------------------------------------------------------------------


def mlp_train_step(policy: str, memory: bool, layers=None, batch=None, k=None):
    """Full Mem-AOP-GD train step for an L-layer relu MLP with softmax head.

    Flat positional signature (L = len(layers) - 1 dense layers):
      (x, y, w_1..w_L, b_1..b_L, mx_1..mx_L, mg_1..mg_L,
       noise_1..noise_L, eta) ->
      (loss, acc, w'_1..w'_L, b'_1..b'_L, mx'_1..mx'_L, mg'_1..mg'_L)

    Every dense weight gradient goes through the Pallas AOP kernel with the
    baked ``policy``/``k``; bias gradients stay exact; ``memory=False``
    zeroes the kept rows so the memories remain 0.
    """
    layers = layers or MLP_LAYERS
    batch = batch or MLP_BATCH
    k = k or MLP_K
    n_layers = len(layers) - 1

    def fn(*args):
        x, y = args[0], args[1]
        off = 2
        ws = list(args[off : off + n_layers])
        bs = list(args[off + n_layers : off + 2 * n_layers])
        mxs = list(args[off + 2 * n_layers : off + 3 * n_layers])
        mgs = list(args[off + 3 * n_layers : off + 4 * n_layers])
        noises = list(args[off + 4 * n_layers : off + 5 * n_layers])
        eta = args[off + 5 * n_layers]

        # forward, keeping layer inputs and pre-activations
        acts = [x]
        zs = []
        h = x
        for i in range(n_layers):
            z = h @ ws[i] + bs[i]
            zs.append(z)
            h = jax.nn.relu(z) if i < n_layers - 1 else z
            acts.append(h)

        loss, g = _softmax_cce(acts[-1], y)
        acc = jnp.mean(
            (jnp.argmax(acts[-1], axis=1) == jnp.argmax(y, axis=1)).astype(
                jnp.float32
            )
        )

        se = jnp.sqrt(eta)
        new_ws, new_bs, new_mxs, new_mgs = [], [], [], []
        # backward with per-layer Mem-AOP-GD on the weight gradients
        for i in reversed(range(n_layers)):
            xin = acts[i]
            xhat = mxs[i] + se * xin
            ghat = mgs[i] + se * g
            s = scores_kernel(xhat, ghat)
            mask = _select_mask(policy, s, noises[i], k)
            keep = (1.0 - mask) if memory else jnp.zeros_like(mask)
            wstar = aop_outer(xhat, ghat, mask)
            new_ws.append(ws[i] - wstar)
            new_bs.append(bs[i] - eta * jnp.sum(g, axis=0))
            new_mxs.append(row_scale(xhat, keep))
            new_mgs.append(row_scale(ghat, keep))
            if i > 0:
                # eq. (2a): propagate through the *pre-update* weights
                g = (g @ ws[i].T) * (zs[i - 1] > 0).astype(jnp.float32)
        new_ws.reverse()
        new_bs.reverse()
        new_mxs.reverse()
        new_mgs.reverse()
        return (loss, acc, *new_ws, *new_bs, *new_mxs, *new_mgs)

    return fn, layers, batch, n_layers


def mlp_eval(layers=None, batch=None):
    """MLP validation graph: (x, y, w_1..w_L, b_1..b_L) -> (loss, acc)."""
    layers = layers or MLP_LAYERS
    batch = batch or MLP_BATCH
    n_layers = len(layers) - 1

    def fn(*args):
        x, y = args[0], args[1]
        ws = list(args[2 : 2 + n_layers])
        bs = list(args[2 + n_layers : 2 + 2 * n_layers])
        h = x
        for i in range(n_layers):
            z = h @ ws[i] + bs[i]
            h = jax.nn.relu(z) if i < n_layers - 1 else z
        loss, _ = _softmax_cce(h, y)
        acc = jnp.mean(
            (jnp.argmax(h, axis=1) == jnp.argmax(y, axis=1)).astype(jnp.float32)
        )
        return loss, acc

    return fn, layers, batch, n_layers
