"""Layer-1 Pallas kernel: error-feedback memory update (alg. lines 8-9).

    m_{t+1,(k)} = X̂_{t,(k)}   for k not selected,
    m_{t+1,(k)} = 0            for k selected,

expressed as a per-row rescale ``out[m, :] = keep[m] * a[m, :]`` with
``keep = 1 - selected``. Purely bandwidth-bound; blocks stream row tiles
through VMEM once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _divisor_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _row_scale_kernel(a_ref, k_ref, o_ref):
    o_ref[...] = a_ref[...] * k_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def row_scale(
    a: jnp.ndarray, keep: jnp.ndarray, *, bm: int = 512, bn: int = 1024
) -> jnp.ndarray:
    """Per-row rescale ``out[m,:] = keep[m] * a[m,:]`` via Pallas.

    Args:
      a: ``(M, N)`` float32 — memory-folded matrix (X̂ or Ĝ).
      keep: ``(M,)`` float32 — 1 for rows to retain in memory, 0 for rows
        consumed by the update.

    Returns:
      ``(M, N)`` float32 new memory matrix.
    """
    m, n = a.shape
    assert keep.shape == (m,), (a.shape, keep.shape)
    bm = _divisor_block(m, bm)
    bn = _divisor_block(n, bn)
    k2 = keep.reshape(m, 1).astype(jnp.float32)
    return pl.pallas_call(
        _row_scale_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), k2)
