"""Layer-1 Pallas kernel: masked scaled outer-product accumulation (AOP).

This is the computational hot-spot of Mem-AOP-GD (alg. line 6):

    Ŵ*[n, p] = sum_m  s[m] * X[m, n] * G[m, p]

i.e. the sum of the K *selected* rank-1 outer products of eq. (4)/(5), with
selection and the optional unbiased ``1/(p_k K)`` weighting folded into the
per-row scale vector ``s`` (``s[m] = 0`` for unselected rows).

TPU mapping (DESIGN.md §8 Hardware-Adaptation): the output (N, P) tile is
*stationary* in VMEM while the M (batch/outer-product) axis is streamed
through the MXU as a contraction — ``(X * s)^T @ G`` on each block triple.
On a real TPU the selected rows would first be *compacted* into dense
(K, bn)/(K, bp) VMEM tiles so the contraction length is K, realising the
paper's K/M FLOP reduction; under ``interpret=True`` (mandatory on CPU
PJRT) we keep mask semantics, which is bit-identical numerically.

The kernel tiles the output over a (N/bn, P/bp, M/bm) grid with the M axis
innermost and accumulates into the stationary output block — the classic
double-buffered reduction schedule Pallas emits for ``BlockSpec`` grids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _divisor_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target`` (>= 1).

    Pallas grids are cleanest when block shapes divide the array shape; our
    shapes are static at trace time so we simply pick a dividing block.
    """
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _aop_kernel(x_ref, g_ref, s_ref, o_ref):
    """One (bn, bp) output block: accumulate ``(x * s)^T @ g`` over M blocks."""
    m_idx = pl.program_id(2)

    @pl.when(m_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bm, bn)
    g = g_ref[...]  # (bm, bp)
    s = s_ref[...]  # (bm, 1)
    # Row-scale then contract over the bm axis on the MXU.
    o_ref[...] += jnp.dot(
        (x * s).T, g, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bp"))
def aop_outer(
    x: jnp.ndarray,
    g: jnp.ndarray,
    s: jnp.ndarray,
    *,
    bm: int = 512,
    bn: int = 1024,
    bp: int = 1024,
) -> jnp.ndarray:
    """Masked scaled outer-product sum via Pallas.

    Args:
      x: ``(M, N)`` float32 — memory-folded activations ``X̂``.
      g: ``(M, P)`` float32 — memory-folded output gradients ``Ĝ``.
      s: ``(M,)`` float32 — per-row selection scale (0 = row not selected).
      bm/bn/bp: target block sizes (clamped to dividing blocks).

    Returns:
      ``(N, P)`` float32 approximate weight gradient.
    """
    m, n = x.shape
    m2, p = g.shape
    assert m == m2 and s.shape == (m,), (x.shape, g.shape, s.shape)
    bm = _divisor_block(m, bm)
    bn = _divisor_block(n, bn)
    bp = _divisor_block(p, bp)
    s2 = s.reshape(m, 1).astype(jnp.float32)

    grid = (n // bn, p // bp, m // bm)
    return pl.pallas_call(
        _aop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, i)),
            pl.BlockSpec((bm, bp), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.float32), g.astype(jnp.float32), s2)
