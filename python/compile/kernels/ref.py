"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package must match its oracle to float32 tolerance across the shape /
mask / scale sweeps in ``python/tests/``.

All oracles operate on float32 and mirror the math of Sec. II-B / III of the
paper (Mem-AOP-GD):

  * ``aop_outer_ref``  — masked, per-row-scaled outer-product accumulation
                         ``C = sum_m s_m * X[m,:]^T G[m,:]``  (eq. (4)/(5)).
  * ``scores_ref``     — selection-policy scores
                         ``s_m = ||X_(m)||_2 * ||G_(m)||_2`` (Sec. II-B).
  * ``row_scale_ref``  — per-row rescaling ``out[m,:] = keep[m] * A[m,:]``
                         (memory update, alg. lines 8-9).
"""

from __future__ import annotations

import jax.numpy as jnp


def aop_outer_ref(x: jnp.ndarray, g: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Masked scaled outer-product sum: ``C[n,p] = sum_m s[m] x[m,n] g[m,p]``.

    Args:
      x: ``(M, N)`` activations (rows are the outer-product columns of X^T).
      g: ``(M, P)`` output gradients.
      s: ``(M,)`` per-row scale; 0 for unselected rows, 1 (or the unbiased
         ``1/(p_k K)`` weight) for selected rows.

    Returns:
      ``(N, P)`` approximate weight gradient ``Ŵ*``.
    """
    return (x * s[:, None]).T @ g


def scores_ref(x: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Row-norm-product policy scores ``s_m = ||x[m,:]|| * ||g[m,:]||``."""
    xn = jnp.sqrt(jnp.sum(x * x, axis=1))
    gn = jnp.sqrt(jnp.sum(g * g, axis=1))
    return xn * gn


def row_scale_ref(a: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Per-row rescale: ``out[m,:] = keep[m] * a[m,:]`` (memory update)."""
    return a * keep[:, None]
