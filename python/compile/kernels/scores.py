"""Layer-1 Pallas kernel: selection-policy scores (Sec. II-B).

Computes, for every outer-product index m, the row-norm product

    s_m = ||X̂_(m)||_2 * ||Ĝ_(m)||_2

which is the ranking statistic of topK and the (unnormalised) sampling
weight of weightedK. The kernel fuses both squared-row-norm reductions and
the sqrt/product into one pass over each M block, so X̂/Ĝ stream through
VMEM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _divisor_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _scores_kernel(x_ref, g_ref, s_ref):
    x = x_ref[...]  # (bm, N)
    g = g_ref[...]  # (bm, P)
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    gn = jnp.sum(g * g, axis=1, keepdims=True)
    s_ref[...] = jnp.sqrt(xn) * jnp.sqrt(gn)


@functools.partial(jax.jit, static_argnames=("bm",))
def scores(x: jnp.ndarray, g: jnp.ndarray, *, bm: int = 512) -> jnp.ndarray:
    """Row-norm-product scores ``s_m = ||x[m,:]|| * ||g[m,:]||``.

    Args:
      x: ``(M, N)`` float32.
      g: ``(M, P)`` float32.

    Returns:
      ``(M,)`` float32 scores.
    """
    m, n = x.shape
    m2, p = g.shape
    assert m == m2, (x.shape, g.shape)
    bm = _divisor_block(m, bm)
    out = pl.pallas_call(
        _scores_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, p), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), g.astype(jnp.float32))
    return out.reshape(m)
