"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

The hypothesis sweeps randomize shapes, masks, scales and block sizes; a
kernel is correct only if it matches ``ref.py`` to float32 tolerance on all
of them. This is the core correctness signal for the AOT path — the same
kernels are baked into every HLO artifact the Rust coordinator executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.aop_outer import aop_outer, _divisor_block
from compile.kernels.memupd import row_scale
from compile.kernels.scores import scores

DIM = st.integers(min_value=1, max_value=96)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# aop_outer
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(m=DIM, n=DIM, p=DIM, seed=st.integers(0, 2**31 - 1))
def test_aop_outer_matches_ref(m, n, p, seed):
    kx, kg, km = keys(seed, 3)
    x, g = rand(kx, (m, n)), rand(kg, (m, p))
    s = (jax.random.uniform(km, (m,)) > 0.5).astype(jnp.float32)
    np.testing.assert_allclose(
        aop_outer(x, g, s), ref.aop_outer_ref(x, g, s), rtol=3e-5, atol=3e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    m=DIM,
    n=DIM,
    p=DIM,
    seed=st.integers(0, 2**31 - 1),
    bm=st.integers(1, 64),
    bn=st.integers(1, 64),
    bp=st.integers(1, 64),
)
def test_aop_outer_block_size_invariance(m, n, p, seed, bm, bn, bp):
    """The result must not depend on the BlockSpec tiling."""
    kx, kg, km = keys(seed, 3)
    x, g = rand(kx, (m, n)), rand(kg, (m, p))
    s = jax.random.uniform(km, (m,))
    a = aop_outer(x, g, s, bm=bm, bn=bn, bp=bp)
    b = ref.aop_outer_ref(x, g, s)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


def test_aop_outer_zero_mask_is_zero():
    kx, kg, _ = keys(0, 3)
    x, g = rand(kx, (32, 7)), rand(kg, (32, 5))
    out = aop_outer(x, g, jnp.zeros((32,)))
    assert np.all(np.asarray(out) == 0.0)


def test_aop_outer_full_mask_is_exact_matmul():
    kx, kg, _ = keys(1, 3)
    x, g = rand(kx, (64, 16)), rand(kg, (64, 10))
    np.testing.assert_allclose(
        aop_outer(x, g, jnp.ones((64,))), x.T @ g, rtol=3e-5, atol=3e-5
    )


def test_aop_outer_single_row_is_rank_one():
    """One selected row == one outer product (Fig. 1 of the paper)."""
    kx, kg, _ = keys(2, 3)
    x, g = rand(kx, (16, 8)), rand(kg, (16, 4))
    s = jnp.zeros((16,)).at[5].set(1.0)
    expect = jnp.outer(x[5], g[5])
    np.testing.assert_allclose(aop_outer(x, g, s), expect, rtol=3e-5, atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(m=DIM, seed=st.integers(0, 2**31 - 1))
def test_aop_outer_mask_complement_decomposition(m, seed):
    """masked(C, s) + masked(C, 1-s) == full matmul — the eq. (7) identity."""
    kx, kg, km = keys(seed, 3)
    x, g = rand(kx, (m, 12)), rand(kg, (m, 6))
    s = (jax.random.uniform(km, (m,)) > 0.4).astype(jnp.float32)
    both = aop_outer(x, g, s) + aop_outer(x, g, 1.0 - s)
    np.testing.assert_allclose(both, x.T @ g, rtol=1e-4, atol=1e-4)


def test_aop_outer_paper_shapes():
    """The exact shapes of Fig. 2 (energy) and Fig. 3 (mnist)."""
    for (m, n, p) in [(144, 16, 1), (64, 784, 10)]:
        kx, kg, km = keys(m, 3)
        x, g = rand(kx, (m, n)), rand(kg, (m, p))
        s = (jax.random.uniform(km, (m,)) > 0.75).astype(jnp.float32)
        np.testing.assert_allclose(
            aop_outer(x, g, s), ref.aop_outer_ref(x, g, s), rtol=3e-5, atol=3e-5
        )


def test_aop_outer_unbiased_scaling():
    """With-replacement weightedK scaling (eq. (5)) averages to the true C."""
    rng = np.random.default_rng(0)
    m, n, p, k = 24, 6, 4, 6
    x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
    sc = np.asarray(ref.scores_ref(x, g))
    prob = sc / sc.sum()
    xn, gn = np.asarray(x, np.float64), np.asarray(g, np.float64)
    true = xn.T @ gn

    def mc_error(trials):
        # vectorized: counts[t, i] = how often row i was drawn in trial t
        idx = rng.choice(m, size=(trials, k), p=prob, replace=True)
        counts = np.zeros((trials, m))
        np.add.at(counts, (np.arange(trials)[:, None], idx), 1.0)
        scales = counts / (prob[None, :] * k)  # eq. (5) weights
        mean_scale = scales.mean(axis=0)
        est = (xn * mean_scale[:, None]).T @ gn
        return np.abs(est - true).max()

    e_small, e_big = mc_error(500), mc_error(32000)
    # the eq. (5) estimator is unbiased: error must decay with trials and
    # be small in absolute terms at 32k trials (std-err ~ 1/sqrt(T))
    assert e_big < 0.25, (e_small, e_big)
    assert e_big < e_small, (e_small, e_big)


# ---------------------------------------------------------------------------
# scores
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(m=DIM, n=DIM, p=DIM, seed=st.integers(0, 2**31 - 1))
def test_scores_matches_ref(m, n, p, seed):
    kx, kg, _ = keys(seed, 3)
    x, g = rand(kx, (m, n)), rand(kg, (m, p))
    np.testing.assert_allclose(
        scores(x, g), ref.scores_ref(x, g), rtol=1e-5, atol=1e-6
    )


def test_scores_nonnegative_and_zero_rows():
    x = jnp.zeros((8, 5)).at[3].set(1.0)
    g = jnp.ones((8, 2))
    s = np.asarray(scores(x, g))
    assert (s >= 0).all()
    assert s[0] == 0.0 and s[3] > 0.0


def test_scores_scale_homogeneity():
    """s(aX, bG) = |ab| s(X, G) — norms are absolutely homogeneous."""
    kx, kg, _ = keys(7, 3)
    x, g = rand(kx, (16, 9)), rand(kg, (16, 3))
    np.testing.assert_allclose(
        scores(2.0 * x, -3.0 * g), 6.0 * scores(x, g), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# row_scale (memory update)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(m=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_row_scale_matches_ref(m, n, seed):
    ka, km, _ = keys(seed, 3)
    a = rand(ka, (m, n))
    keep = (jax.random.uniform(km, (m,)) > 0.5).astype(jnp.float32)
    np.testing.assert_allclose(row_scale(a, keep), ref.row_scale_ref(a, keep))


def test_row_scale_partitions_rows():
    """keep + (1-keep) reconstructs the input exactly (memory invariant)."""
    ka, km, _ = keys(3, 3)
    a = rand(ka, (32, 11))
    keep = (jax.random.uniform(km, (32,)) > 0.5).astype(jnp.float32)
    np.testing.assert_allclose(
        row_scale(a, keep) + row_scale(a, 1.0 - keep), a, rtol=1e-6
    )


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dim,target,expect",
    [(144, 128, 72), (64, 128, 64), (1, 128, 1), (97, 64, 1), (100, 64, 50)],
)
def test_divisor_block(dim, target, expect):
    b = _divisor_block(dim, target)
    assert b == expect and dim % b == 0 and b <= max(1, min(dim, target))
