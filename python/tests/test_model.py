"""Layer-2 correctness: graph semantics vs straight-line Algorithm 1.

Verifies that (a) the two-phase fwd_score/apply split composes into exactly
one step of the paper's Algorithm 1, (b) the exact variant reproduces the
classic SGD step obtained by jax.grad, and (c) the monolithic MLP step is
consistent with autodiff in its exact configuration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_enable_x64", False)


def _data(task, seed=0):
    cfg = model.TASKS[task]
    m, n, p = cfg["batch"], cfg["n_in"], cfg["n_out"]
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (m, n), jnp.float32)
    if cfg["loss"] == "mse":
        y = jax.random.normal(ks[1], (m, p), jnp.float32)
    else:
        y = jax.nn.one_hot(
            jax.random.randint(ks[1], (m,), 0, p), p, dtype=jnp.float32
        )
    w = 0.1 * jax.random.normal(ks[2], (n, p), jnp.float32)
    b = jnp.zeros((p,), jnp.float32)
    return cfg, x, y, w, b


def _loss_fn(task):
    cfg = model.TASKS[task]
    if cfg["loss"] == "mse":
        return lambda w, b, x, y: jnp.mean((x @ w + b - y) ** 2)
    return lambda w, b, x, y: -jnp.mean(
        jnp.sum(y * jax.nn.log_softmax(x @ w + b, axis=1), axis=1)
    )


@pytest.mark.parametrize("task", ["energy", "mnist"])
def test_exact_two_phase_equals_sgd(task):
    """mask=1, keep=0 ⇒ the two-phase path is one classic SGD step."""
    cfg, x, y, w, b = _data(task)
    m = cfg["batch"]
    eta = jnp.float32(0.01)
    mem_x = jnp.zeros_like(x)
    mem_g = jnp.zeros((m, cfg["n_out"]), jnp.float32)

    loss, xhat, ghat, db, s = model.fwd_score(task)(x, y, w, b, mem_x, mem_g, eta)
    ones, zeros = jnp.ones((m,)), jnp.zeros((m,))
    w_new, b_new, mx_new, mg_new, fro = model.apply_update(task)(
        xhat, ghat, w, b, db, ones, zeros
    )

    lf = _loss_fn(task)
    gw, gb = jax.grad(lf, argnums=(0, 1))(w, b, x, y)
    np.testing.assert_allclose(loss, lf(w, b, x, y), rtol=1e-5)
    np.testing.assert_allclose(w_new, w - eta * gw, rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(b_new, b - eta * gb, rtol=2e-4, atol=1e-6)
    assert np.all(np.asarray(mx_new) == 0) and np.all(np.asarray(mg_new) == 0)
    assert float(fro) > 0


@pytest.mark.parametrize("task", ["energy", "mnist"])
def test_memory_retains_unselected_rows(task):
    """Alg. lines 8-9: memories hold exactly the unselected rows of X̂/Ĝ."""
    cfg, x, y, w, b = _data(task, seed=1)
    m = cfg["batch"]
    eta = jnp.float32(0.01)
    mem_x = 0.01 * jnp.ones_like(x)
    mem_g = jnp.zeros((m, cfg["n_out"]), jnp.float32)

    _, xhat, ghat, db, s = model.fwd_score(task)(x, y, w, b, mem_x, mem_g, eta)
    k = m // 4
    idx = jnp.argsort(-s)[:k]
    mask = jnp.zeros((m,)).at[idx].set(1.0)
    _, _, mx_new, mg_new, _ = model.apply_update(task)(
        xhat, ghat, w, b, db, mask, 1.0 - mask
    )
    mx_new, mg_new = np.asarray(mx_new), np.asarray(mg_new)
    sel = np.asarray(idx)
    assert np.all(mx_new[sel] == 0) and np.all(mg_new[sel] == 0)
    unsel = np.setdiff1d(np.arange(m), sel)
    np.testing.assert_allclose(mx_new[unsel], np.asarray(xhat)[unsel])
    np.testing.assert_allclose(mg_new[unsel], np.asarray(ghat)[unsel])


@pytest.mark.parametrize("task", ["energy", "mnist"])
def test_memory_fold_matches_alg_lines_3_4(task):
    cfg, x, y, w, b = _data(task, seed=2)
    m = cfg["batch"]
    eta = jnp.float32(0.04)
    mem_x = jax.random.normal(jax.random.PRNGKey(9), x.shape) * 0.1
    mem_g = jax.random.normal(jax.random.PRNGKey(10), (m, cfg["n_out"])) * 0.1
    loss, xhat, ghat, db, s = model.fwd_score(task)(x, y, w, b, mem_x, mem_g, eta)
    np.testing.assert_allclose(xhat, mem_x + jnp.sqrt(eta) * x, rtol=1e-6)
    # ghat = mem_g + sqrt(eta) * dL/dO, recomputed from the loss definition
    o = x @ w + b
    if cfg["loss"] == "mse":
        g = 2.0 * (o - y) / (o.shape[0] * o.shape[1])
    else:
        g = (jax.nn.softmax(o, axis=1) - y) / o.shape[0]
    np.testing.assert_allclose(ghat, mem_g + jnp.sqrt(eta) * g, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(db, eta * jnp.sum(g, axis=0), rtol=1e-5, atol=1e-8)


def test_eval_accuracy_mnist():
    cfg, x, y, w, b = _data("mnist", seed=3)
    loss, acc = model.evaluate("mnist")(x, y, w, b)
    o = x @ w + b
    expect = np.mean(np.argmax(np.asarray(o), 1) == np.argmax(np.asarray(y), 1))
    np.testing.assert_allclose(acc, expect, rtol=1e-6)
    assert float(loss) > 0


def _mlp_args(policy_seed=0, layers=(20, 16, 10), batch=8):
    layers = list(layers)
    nl = len(layers) - 1
    ks = jax.random.split(jax.random.PRNGKey(policy_seed), 3 + 2 * nl)
    x = jax.random.normal(ks[0], (batch, layers[0]), jnp.float32)
    y = jax.nn.one_hot(
        jax.random.randint(ks[1], (batch,), 0, layers[-1]), layers[-1]
    ).astype(jnp.float32)
    ws = [
        0.3 * jax.random.normal(ks[2 + i], (layers[i], layers[i + 1]), jnp.float32)
        for i in range(nl)
    ]
    bs = [jnp.zeros((layers[i + 1],), jnp.float32) for i in range(nl)]
    mxs = [jnp.zeros((batch, layers[i]), jnp.float32) for i in range(nl)]
    mgs = [jnp.zeros((batch, layers[i + 1]), jnp.float32) for i in range(nl)]
    noises = [
        jax.random.uniform(ks[2 + nl + i], (batch,), jnp.float32)
        for i in range(nl)
    ]
    return layers, nl, x, y, ws, bs, mxs, mgs, noises


def test_mlp_exact_matches_autodiff():
    """policy='exact' ⇒ the monolithic step is one plain SGD step."""
    layers, nl, x, y, ws, bs, mxs, mgs, noises = _mlp_args()
    eta = jnp.float32(0.05)
    fn, _, _, _ = model.mlp_train_step("exact", False, layers, 8, 4)
    out = fn(x, y, *ws, *bs, *mxs, *mgs, *noises, eta)
    loss, acc = out[0], out[1]
    new_ws = out[2 : 2 + nl]
    new_bs = out[2 + nl : 2 + 2 * nl]

    def lf(ws, bs):
        h = x
        for i in range(nl):
            z = h @ ws[i] + bs[i]
            h = jax.nn.relu(z) if i < nl - 1 else z
        return -jnp.mean(jnp.sum(y * jax.nn.log_softmax(h, 1), 1))

    gws, gbs = jax.grad(lf, argnums=(0, 1))(ws, bs)
    np.testing.assert_allclose(loss, lf(ws, bs), rtol=1e-5)
    for i in range(nl):
        np.testing.assert_allclose(
            new_ws[i], ws[i] - eta * gws[i], rtol=2e-4, atol=1e-6
        )
        np.testing.assert_allclose(
            new_bs[i], bs[i] - eta * gbs[i], rtol=2e-4, atol=1e-6
        )


@pytest.mark.parametrize("policy", ["topk", "randk", "weightedk"])
def test_mlp_selection_policies_run_and_keep_memory(policy):
    layers, nl, x, y, ws, bs, mxs, mgs, noises = _mlp_args(policy_seed=4)
    eta = jnp.float32(0.05)
    k = 3
    fn, _, _, _ = model.mlp_train_step(policy, True, layers, 8, k)
    out = fn(x, y, *ws, *bs, *mxs, *mgs, *noises, eta)
    new_mxs = out[2 + 2 * nl : 2 + 3 * nl]
    for mx in new_mxs:
        # exactly batch-k rows are retained (nonzero) in each memory
        nz_rows = np.count_nonzero(np.abs(np.asarray(mx)).sum(1) > 0)
        assert nz_rows == 8 - k, (policy, nz_rows)


def test_mlp_nomem_keeps_memories_zero():
    layers, nl, x, y, ws, bs, mxs, mgs, noises = _mlp_args(policy_seed=5)
    fn, _, _, _ = model.mlp_train_step("topk", False, layers, 8, 3)
    out = fn(x, y, *ws, *bs, *mxs, *mgs, *noises, jnp.float32(0.05))
    for mx in out[2 + 2 * nl : 2 + 4 * nl]:
        assert np.all(np.asarray(mx) == 0)


def test_select_mask_topk_selects_largest():
    s = jnp.asarray([0.1, 5.0, 0.2, 3.0, 0.05], jnp.float32)
    mask = model._select_mask("topk", s, jnp.zeros(5), 2)
    np.testing.assert_array_equal(np.asarray(mask), [0, 1, 0, 1, 0])


def test_select_mask_exact_is_all_ones():
    mask = model._select_mask("exact", jnp.ones(6), jnp.zeros(6), 2)
    assert np.all(np.asarray(mask) == 1)


def test_select_mask_randk_cardinality():
    noise = jax.random.uniform(jax.random.PRNGKey(0), (31,))
    mask = model._select_mask("randk", jnp.ones(31), noise, 7)
    assert int(np.asarray(mask).sum()) == 7


def test_select_mask_weightedk_prefers_high_scores():
    """Gumbel-top-k: high-score rows must be selected far more often."""
    s = jnp.asarray([10.0] * 4 + [0.01] * 12, jnp.float32)
    hits = np.zeros(16)
    for i in range(200):
        noise = jax.random.uniform(jax.random.PRNGKey(i), (16,))
        hits += np.asarray(model._select_mask("weightedk", s, noise, 4))
    assert hits[:4].mean() > 5 * hits[4:].mean()
