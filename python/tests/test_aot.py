"""AOT pipeline integrity: manifest ⇄ artifacts ⇄ lowering agree.

These tests exercise ``compile.aot`` itself (lowering into a temp dir) so
they do not depend on ``make artifacts`` having been run; a separate
(skippable) section validates the checked-out ``artifacts/`` directory when
present, which is what the Rust runtime will consume.
"""

import hashlib
import json
import os

import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_simple(tmp_path):
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda a, b: (a @ b + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[2,2]" in text


@pytest.mark.parametrize("task", ["energy", "mnist"])
def test_task_artifacts_lower_and_declare_shapes(tmp_path, task):
    arts = aot.task_artifacts(task, str(tmp_path))
    cfg = model.TASKS[task]
    m, n, p = cfg["batch"], cfg["n_in"], cfg["n_out"]
    fs = arts[f"{task}_fwd_score"]
    assert [i["shape"] for i in fs["inputs"]] == [
        [m, n], [m, p], [n, p], [p], [m, n], [m, p], [],
    ]
    assert [o["name"] for o in fs["outputs"]] == [
        "loss", "xhat", "ghat", "db", "scores",
    ]
    assert fs["outputs"][4]["shape"] == [m]
    ap = arts[f"{task}_apply"]
    assert ap["outputs"][0]["shape"] == [n, p]
    for a in arts.values():
        text = open(tmp_path / a["file"]).read()
        assert "ENTRY" in text
        assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"]


def test_mlp_artifact_signature(tmp_path):
    arts = aot.mlp_artifacts(str(tmp_path))
    nl = len(model.MLP_LAYERS) - 1
    tr = arts["mlp_topk_mem"]
    assert len(tr["inputs"]) == 2 + 5 * nl + 1
    assert len(tr["outputs"]) == 2 + 4 * nl
    ev = arts["mlp_eval"]
    assert len(ev["inputs"]) == 2 + 2 * nl
    assert [o["name"] for o in ev["outputs"]] == ["loss", "acc"]


# ---------------------------------------------------------------------------
# validation of the built artifacts/ directory (if present)
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_built_manifest_files_exist_and_hash():
    manifest = json.load(open(os.path.join(ART_DIR, "manifest.json")))
    assert manifest["version"] == 1
    assert set(manifest["tasks"]) == {"energy", "mnist"}
    for name, a in manifest["artifacts"].items():
        path = os.path.join(ART_DIR, a["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"], name
        assert "ENTRY" in text


@needs_artifacts
def test_built_manifest_matches_current_model_config():
    manifest = json.load(open(os.path.join(ART_DIR, "manifest.json")))
    for task, cfg in model.TASKS.items():
        assert manifest["tasks"][task]["batch"] == cfg["batch"]
    assert manifest["mlp"]["layers"] == model.MLP_LAYERS
