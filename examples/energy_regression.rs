//! Fig. 2 workload in miniature: the energy-efficiency regression task
//! across all selection policies and compression levels, printing the
//! panel summaries the paper's Fig. 2 plots.
//!
//! Demonstrates the sweep API (`panel_configs` + `run_sweep`) — the same
//! machinery `repro figure --fig 2` uses at full scale.

use anyhow::Result;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig};
use mem_aop_gd::coordinator::figures::print_panel_summary;
use mem_aop_gd::coordinator::sweep;

fn main() -> Result<()> {
    let mut base = ExperimentConfig::energy_preset();
    base.backend = Backend::Native; // pure-Rust reference path
    base.epochs = 60;

    // The paper's three compression levels: K = 18, 9, 3 of M = 144.
    for k in base.task.figure_ks() {
        let configs = sweep::panel_configs(&base, k);
        let results = sweep::run_sweep(&configs, 7);
        let ok: Vec<_> = results.into_iter().collect::<Result<Vec<_>>>()?;
        print_panel_summary(2, k, &ok);
    }
    println!(
        "\n(paper shape to look for: at K=18 the with-memory series match or\n\
         beat the baseline; as K shrinks the memory advantage fades — Fig. 2)"
    );
    Ok(())
}
