//! Quickstart: train the paper's energy-regression model with Mem-AOP-GD
//! through the full AOT stack (Pallas kernel → HLO artifact → PJRT), and
//! compare against exact back-propagation.
//!
//! Run with:
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule};
use mem_aop_gd::coordinator::experiment;

fn main() -> Result<()> {
    // 1. Baseline: exact back-propagation (all M = 144 outer products).
    let mut baseline = ExperimentConfig::energy_preset();
    baseline.epochs = 40;
    baseline.backend = Backend::Hlo; // the AOT/PJRT path

    // 2. Mem-AOP-GD: only K = 18 of 144 outer products per update (an 8×
    //    reduction of the weight-gradient computation), with
    //    error-feedback memory compensating the approximation.
    let mut aop = baseline.clone();
    aop.policy = Policy::TopK;
    aop.k = KSchedule::Constant(18);
    aop.memory = true;

    println!("== exact back-propagation (baseline) ==");
    let rb = experiment::run(&baseline)?;
    println!(
        "final val MSE {:.5}   backward FLOPs {:.2e}",
        rb.final_val_loss(),
        rb.curve.total_backward_flops() as f64
    );

    println!("\n== Mem-AOP-GD, topK, K=18/144, with memory ==");
    let ra = experiment::run(&aop)?;
    println!(
        "final val MSE {:.5}   backward FLOPs {:.2e}",
        ra.final_val_loss(),
        ra.curve.total_backward_flops() as f64
    );

    let flop_ratio =
        ra.curve.total_backward_flops() as f64 / rb.curve.total_backward_flops() as f64;
    println!(
        "\nMem-AOP-GD used {:.1}% of the baseline's weight-gradient FLOPs \
         and reached val loss {:.5} vs baseline {:.5}",
        flop_ratio * 100.0,
        ra.final_val_loss(),
        rb.final_val_loss()
    );
    Ok(())
}
