//! Ablation: how the pieces of Mem-AOP-GD contribute (DESIGN.md's design
//! choices, exercised as an experiment):
//!
//! 1. selection policy (topK vs randK vs weightedK vs weightedK-with-
//!    replacement + unbiased scaling),
//! 2. error-feedback memory on/off,
//! 3. compression level K,
//! 4. seed sensitivity (3 seeds per cell).
//!
//! Runs on the native backend for speed; prints a tail-mean val-loss grid.

use anyhow::Result;
use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule};
use mem_aop_gd::coordinator::sweep;
use mem_aop_gd::metrics::print_table;

fn main() -> Result<()> {
    let policies = [
        Policy::TopK,
        Policy::RandK,
        Policy::WeightedK,
        Policy::WeightedKReplacement,
    ];
    let seeds = [0u64, 1, 2];

    let mut configs = Vec::new();
    for &k in &[18usize, 9, 3] {
        for &p in &policies {
            for &mem in &[true, false] {
                for &seed in &seeds {
                    let mut c = ExperimentConfig::energy_preset();
                    c.backend = Backend::Native;
                    c.epochs = 60;
                    c.policy = p;
                    c.k = KSchedule::constant(k);
                    c.memory = mem;
                    c.seed = seed;
                    configs.push(c);
                }
            }
        }
    }
    // plus the baseline per seed
    for &seed in &seeds {
        let mut c = ExperimentConfig::energy_preset();
        c.backend = Backend::Native;
        c.epochs = 60;
        c.seed = seed;
        configs.push(c);
    }

    eprintln!("running {} experiments...", configs.len());
    let results = sweep::run_sweep(&configs, 0usize.max(8));

    // aggregate: mean tail loss over seeds per (k, policy, mem)
    let mut rows = Vec::new();
    let cell = |k: usize, p: Option<Policy>, mem: bool| -> String {
        let vals: Vec<f32> = results
            .iter()
            .filter_map(|r| r.as_ref().ok())
            .filter(|r| match p {
                Some(p) => {
                    r.config.policy == p
                        && r.config.k == KSchedule::Constant(k)
                        && r.config.memory == mem
                }
                None => r.config.policy == Policy::Exact,
            })
            .map(|r| r.curve.tail_mean_val_loss(5))
            .collect();
        if vals.is_empty() {
            return "--".into();
        }
        let mean = vals.iter().sum::<f32>() / vals.len() as f32;
        let sd = (vals.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / vals.len() as f32)
            .sqrt();
        format!("{mean:.4}±{sd:.4}")
    };

    for &k in &[18usize, 9, 3] {
        rows.push(vec![
            format!("K={k}"),
            cell(k, Some(Policy::TopK), true),
            cell(k, Some(Policy::TopK), false),
            cell(k, Some(Policy::RandK), true),
            cell(k, Some(Policy::RandK), false),
            cell(k, Some(Policy::WeightedK), true),
            cell(k, Some(Policy::WeightedKReplacement), true),
        ]);
    }
    rows.push(vec![
        "baseline".into(),
        cell(0, None, false),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
    ]);
    print_table(
        &[
            "", "topk+mem", "topk", "randk+mem", "randk", "wgtk+mem", "wgtk-repl+mem",
        ],
        &rows,
    );
    println!("\n(tail-mean val MSE over the last 5 epochs, mean±sd over 3 seeds)");
    Ok(())
}
