//! END-TO-END DRIVER (the EXPERIMENTS.md §E2E run).
//!
//! Trains a ~1.9M-parameter MLP (784-1024-1024-10) for several hundred
//! steps on the synthetic-digit corpus, with per-layer Mem-AOP-GD
//! (K = 32 of 128 outer products per layer) running through the complete
//! three-layer stack:
//!
//!   Pallas `aop_outer` kernel (L1)
//!     → monolithic `mlp_topk_mem` HLO train-step artifact (L2)
//!       → this Rust coordinator: data, batching, noise, lr, logging (L3)
//!
//! and logs the loss curve against the exact-SGD variant, proving all
//! layers compose on a real workload. Python is not involved at runtime.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train
//! ```

// Clock reads are deliberate here (wall-clock run duration reporting) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use anyhow::Result;
use mem_aop_gd::coordinator::mlp_driver::{train_mlp, MlpVariant};
use mem_aop_gd::data::digits;
use mem_aop_gd::metrics::print_table;
use mem_aop_gd::runtime::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::var("E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let rt = Runtime::from_default_artifacts()?;
    let meta = rt.manifest.mlp.clone();
    println!(
        "e2e: MLP {:?}, batch {}, K {}/layer, {} steps, platform {}",
        meta.layers,
        meta.batch,
        meta.k,
        steps,
        rt.platform()
    );

    println!("generating synthetic digit corpus (12800 train / 1280 val)...");
    let train = digits::digits_dataset(12_800, 0xE2E);
    let val = digits::digits_dataset(1_280, 0xE2E ^ 1);

    let mut tables: Vec<(String, Vec<(usize, f32, f32, f32)>)> = Vec::new();
    for variant in [MlpVariant::TopKMem, MlpVariant::Exact] {
        println!("\n--- training {} ---", variant.label());
        let t0 = std::time::Instant::now();
        let (driver, curve) = train_mlp(&rt, variant, &train, &val, steps, 0.05, 50, 7)?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{} params, {:.1}s total ({:.1} ms/step)",
            driver.num_params(),
            wall,
            wall * 1e3 / steps as f64
        );
        tables.push((
            variant.label().to_string(),
            curve
                .epochs
                .iter()
                .map(|m| (m.epoch, m.train_loss, m.val_loss, m.val_acc))
                .collect(),
        ));
    }

    // side-by-side loss curve
    println!("\nloss curves (train CCE / val CCE / val acc):");
    let (aop_label, aop) = &tables[0];
    let (sgd_label, sgd) = &tables[1];
    let mut rows = Vec::new();
    for (a, s) in aop.iter().zip(sgd.iter()) {
        rows.push(vec![
            format!("{}", a.0),
            format!("{:.4} / {:.4} / {:.3}", a.1, a.2, a.3),
            format!("{:.4} / {:.4} / {:.3}", s.1, s.2, s.3),
        ]);
    }
    print_table(&["step", aop_label, sgd_label], &rows);
    println!(
        "\nMem-AOP-GD evaluated {}/{} outer products per layer per step \
         (backward weight-gradient reduction {:.0}%).",
        meta.k,
        meta.batch,
        (1.0 - meta.k as f64 / meta.batch as f64) * 100.0
    );
    Ok(())
}
