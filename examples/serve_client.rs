//! Concurrency driver for the serve subsystem: fires a burst of training
//! jobs at a Mem-AOP-GD job server over many simultaneous TCP
//! connections, waits for every job to finish, verifies a sample of the
//! returned loss curves bit-for-bit against direct in-process runs, and
//! prints the server's metrics (queue depth, jobs/sec, per-policy FLOP
//! savings).
//!
//! By default it spawns its own server on an ephemeral port, so the full
//! acceptance loop runs standalone:
//!
//! ```sh
//! cargo run --release --example serve_client -- --jobs 64 --conns 16
//! ```
//!
//! Point `--addr` at a running `repro serve` instance to hammer that
//! instead (the in-process server is then skipped).

// Clock reads are deliberate here (client-side latency measurement) — see clippy.toml.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule};
use mem_aop_gd::coordinator::experiment;
use mem_aop_gd::metrics::RunCurve;
use mem_aop_gd::serve::{Client, RetryPolicy, ServeOptions, Server};
use mem_aop_gd::util::cli::Command;

/// Deterministic job mix: cycle through every policy, vary K and seed
/// with the job index. Energy task, 3 epochs — fast enough that 64+ jobs
/// finish in seconds, real enough that curves are non-trivial.
fn job_config(i: usize) -> ExperimentConfig {
    let policies = Policy::all();
    let p = policies[i % policies.len()];
    let mut cfg = ExperimentConfig::energy_preset();
    cfg.policy = p;
    cfg.memory = p != Policy::Exact;
    cfg.k = KSchedule::constant(if p == Policy::Exact {
        cfg.m()
    } else {
        [18, 9, 3][(i / policies.len()) % 3]
    });
    cfg.epochs = 3;
    cfg.seed = i as u64;
    cfg.backend = Backend::Native;
    cfg
}

fn curves_identical(a: &RunCurve, b: &RunCurve) -> bool {
    a.epochs.len() == b.epochs.len()
        && a.epochs.iter().zip(&b.epochs).all(|(x, y)| {
            x.train_loss.to_bits() == y.train_loss.to_bits()
                && x.val_loss.to_bits() == y.val_loss.to_bits()
                && x.backward_flops == y.backward_flops
        })
}

fn main() -> Result<()> {
    let cmd = Command::new("serve_client", "hammer a Mem-AOP-GD training-job server")
        .opt("addr", "", "server address (empty = spawn an in-process server)")
        .opt("jobs", "64", "total jobs to submit")
        .opt("conns", "16", "concurrent client connections")
        .opt("verify", "8", "jobs to re-run locally and compare bit-for-bit")
        .opt("timeout-s", "600", "per-job completion timeout");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cmd.parse(&argv).map_err(|e| anyhow!("{e}"))?;

    let jobs: usize = args.get_parse("jobs")?;
    let conns: usize = args.get_parse("conns")?;
    let verify: usize = args.get_parse("verify")?;
    let timeout = Duration::from_secs(args.get_parse::<u64>("timeout-s")?);
    ensure!(jobs > 0 && conns > 0, "--jobs and --conns must be > 0");

    // spawn an in-process server unless pointed at a running one
    let mut spawned = None;
    let addr = match args.get("addr").filter(|a| !a.is_empty()) {
        Some(a) => a.to_string(),
        None => {
            let server = Server::bind(&ServeOptions {
                addr: "127.0.0.1:0".to_string(),
                workers: 0,
                queue_capacity: jobs.max(64),
                ..ServeOptions::default()
            })?;
            let addr = server.local_addr()?.to_string();
            spawned = Some(std::thread::spawn(move || server.run()));
            addr
        }
    };
    println!("hammering {addr}: {jobs} jobs over {conns} connections");

    // fan out: connection t submits and polls jobs i with i % conns == t
    let t0 = Instant::now();
    let mut completed: Vec<(usize, String, Option<RunCurve>)> = Vec::with_capacity(jobs);
    let mut retries_total: u32 = 0;
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for t in 0..conns.min(jobs) {
            let addr = addr.clone();
            handles.push(scope.spawn(move || -> Result<(Vec<(usize, String, Option<RunCurve>)>, u32)> {
                let mut client = Client::connect(&addr)?;
                // resilient submission (protocol v8): a full queue or a
                // rate limiter answers with `retry_after_ms`, and
                // submit_with_retry backs off deterministically instead
                // of failing the burst
                let policy = RetryPolicy { seed: t as u64, ..RetryPolicy::default() };
                let mine: Vec<usize> = (0..jobs).filter(|i| i % conns == t).collect();
                let mut ids = Vec::with_capacity(mine.len());
                let mut retries: u32 = 0;
                for &i in &mine {
                    let (id, r) = client.submit_with_retry(
                        &job_config(i),
                        &format!("burst-{i}"),
                        &policy,
                    )?;
                    retries += r;
                    ids.push((i, id));
                }
                let mut out = Vec::with_capacity(mine.len());
                for (i, id) in ids {
                    let job = client.wait(id, timeout)?;
                    let state = job
                        .get("state")
                        .and_then(|s| s.as_str())
                        .unwrap_or("?")
                        .to_string();
                    let curve = if state == "done" {
                        Some(client.result(id)?.1)
                    } else {
                        None
                    };
                    out.push((i, state, curve));
                }
                Ok((out, retries))
            }));
        }
        for h in handles {
            let (out, retries) = h.join().expect("client thread panicked")?;
            completed.extend(out);
            retries_total += retries;
        }
        Ok(())
    })?;
    let elapsed = t0.elapsed().as_secs_f64();

    ensure!(
        completed.len() == jobs,
        "dropped jobs: {} of {jobs} accounted for",
        completed.len()
    );
    let done = completed.iter().filter(|(_, s, _)| s == "done").count();
    ensure!(done == jobs, "{} of {jobs} jobs did not finish 'done'", jobs - done);
    println!(
        "{jobs} jobs done in {elapsed:.2}s ({:.1} jobs/s end-to-end), none dropped, \
         {retries_total} submit retries",
        jobs as f64 / elapsed
    );

    // bit-for-bit determinism spot-check against direct in-process runs
    completed.sort_by_key(|(i, _, _)| *i);
    let n_verify = verify.min(jobs);
    for (i, _, curve) in completed.iter().take(n_verify) {
        let served = curve.as_ref().expect("done job without curve");
        let direct = experiment::run(&job_config(*i))?;
        ensure!(
            curves_identical(served, &direct.curve),
            "job {i}: served curve differs from direct run"
        );
    }
    if n_verify > 0 {
        println!("{n_verify} curves verified bit-identical to direct experiment::run");
    }

    // scrape and display server metrics
    let mut client = Client::connect(&addr)?;
    let m = client.metrics()?;
    let g = |k: &str| m.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    println!(
        "server metrics: uptime {:.1}s, {} requests, queue depth {}, {:.2} jobs/s",
        g("uptime_s"),
        g("requests_total") as u64,
        g("queue_depth") as u64,
        g("jobs_per_sec")
    );
    if let Some(pols) = m.get("policies").and_then(|p| p.as_arr()) {
        for p in pols {
            println!(
                "  {:>15}: {} jobs, {:.1}% of exact backward FLOPs saved",
                p.get("policy").and_then(|s| s.as_str()).unwrap_or("?"),
                p.get("jobs").and_then(|n| n.as_f64()).unwrap_or(0.0) as u64,
                100.0 * p.get("saved_frac").and_then(|n| n.as_f64()).unwrap_or(0.0)
            );
        }
    }

    if let Some(handle) = spawned {
        client.shutdown()?;
        handle.join().expect("server thread panicked")?;
        println!("in-process server drained and shut down cleanly");
    }
    Ok(())
}
