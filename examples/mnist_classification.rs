//! Fig. 3 workload in miniature: digit classification (784×10 dense +
//! softmax) under Mem-AOP-GD, via the AOT/PJRT path, on a reduced
//! synthetic-digit corpus.
//!
//! Exercises the two-phase HLO protocol end-to-end: `fwd_score` artifact
//! → Rust policy decision → `apply` artifact, plus chunked validation.

use anyhow::Result;
use mem_aop_gd::aop::Policy;
use mem_aop_gd::coordinator::config::{Backend, ExperimentConfig, KSchedule};
use mem_aop_gd::coordinator::experiment;

fn main() -> Result<()> {
    let scale = 0.05; // 3000 train / 500 val synthetic digits
    for (policy, k, memory, label) in [
        (Policy::Exact, 64, false, "baseline (exact)"),
        (Policy::TopK, 16, true, "topK,   K=16/64, memory"),
        (Policy::TopK, 16, false, "topK,   K=16/64, no mem"),
        (Policy::RandK, 16, true, "randK,  K=16/64, memory"),
        (Policy::WeightedK, 16, true, "wgtK,   K=16/64, memory"),
    ] {
        let mut cfg = ExperimentConfig::mnist_preset();
        cfg.backend = Backend::Hlo;
        cfg.policy = policy;
        cfg.k = KSchedule::constant(k);
        cfg.memory = memory;
        cfg.epochs = 8;
        cfg.data_scale = scale;
        let r = experiment::run(&cfg)?;
        println!(
            "{label:28} val CCE {:.4}  val acc {:.3}  backward FLOPs {:.2e}",
            r.final_val_loss(),
            r.curve.final_val_acc(),
            r.curve.total_backward_flops() as f64
        );
    }
    println!(
        "\n(paper shape: the K=16 Mem-AOP-GD variants track the baseline\n\
         closely at a quarter of the weight-gradient cost — Fig. 3, middle)"
    );
    Ok(())
}
