//! Compile-time stub of the `xla` (PJRT C API) bindings.
//!
//! The offline build environment has no XLA toolchain, but the `hlo`
//! cargo feature must still compile so the AOT/PJRT code path stays
//! type-checked. This crate mirrors the slice of the xla-rs API used by
//! `rust/src/runtime/client.rs`; every entry point that would touch a
//! real PJRT plugin returns [`Error`] with an explanatory message.
//!
//! Production deployments replace this directory with the real xla-rs
//! checkout (same package name, same API) — no source changes needed in
//! the main crate.

use std::fmt;

/// Error raised by every stubbed PJRT entry point.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT toolchain not present (this is the offline \
             `vendor/xla` stub; replace it with the real xla-rs bindings \
             to enable the HLO backend)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_literal_sync"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<Literal>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub): construction always fails with a clear message.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("vendor/xla"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
