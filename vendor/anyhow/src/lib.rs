//! Offline substitute for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this in-tree shim
//! provides the (small) subset of `anyhow`'s API the repository uses:
//!
//! * [`Error`] — a context-carrying error value built from any
//!   `std::error::Error` or a formatted message;
//! * [`Result<T>`] — `Result<T, Error>`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Semantics mirror the real crate where they matter here: `{}` displays
//! the outermost message, `{:#}` displays the whole cause chain joined
//! with `": "`, and `{:?}` shows the chain on separate lines. Like the
//! real `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what allows the blanket `From` impl.

use std::fmt;

/// A context-carrying error: an ordered chain of messages, outermost
/// first (index 0 is the most recent `.context(..)` wrapper).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Prepend a context message (outermost position).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => write!(f, "<empty error>"),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for c in rest {
                        write!(f, "\n    {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// Any `std::error::Error` converts, capturing its `source()` chain.
/// `Error` itself does not implement `std::error::Error`, so this does
/// not conflict with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — the crate-default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` extension for fallible values.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(::std::format!($($t)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing thing");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x={x} too large");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero not allowed");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x=11 too large");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 7: missing thing");

        let o: Option<usize> = None;
        let e = o.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");

        // context on an already-anyhow Result
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xFF])?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("missing thing"));
    }
}
