#!/usr/bin/env bash
# CI gate: formatting, lints, release build, full test suite.
#
#   ./ci.sh          # everything (what CI runs)
#   ./ci.sh --fast   # skip the release build (debug build + tests only)
#
# The build is offline-first: no network access, no XLA toolchain — see
# README.md. Benches are compiled but not run here.

set -euo pipefail
cd "$(dirname "$0")"

fast=0
[ "${1:-}" = "--fast" ] && fast=1

echo "==> cargo fmt --check"
cargo fmt --check

# Determinism-contract lints (README "Static analysis"): RNG stream-domain
# registry, hot-path purity, wire-output ordering, SAFETY coverage,
# metric-name registry. Runs before the build — a contract violation
# should fail in seconds, not after a release compile.
echo "==> repro-lint (determinism-contract static analysis)"
cargo run -q -p repro-lint -- rust/src

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings
cargo clippy -p repro-lint --all-targets -- -D warnings

# the PJRT client only compiles under the `hlo` feature (against the
# vendor/xla stub) — keep it from bit-rotting even though the default
# build never touches it
echo "==> cargo check --features hlo --all-targets"
cargo check --features hlo --all-targets

if [ "$fast" -eq 0 ]; then
  echo "==> cargo build --release"
  cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

# The linter's own suite: fixture-backed rule tests plus the
# self-clean run over rust/src (the root package's `cargo test` does
# not cover workspace members).
echo "==> cargo test -q -p repro-lint"
cargo test -q -p repro-lint

# Multi-thread determinism gate: the exec test suite asserts bit-identical
# curves/weights for threads ∈ {1,2,4,7}; running it under two different
# REPRO_THREADS settings also varies the env-driven pool size
# (`determinism_at_env_worker_count`), so two genuinely different worker
# pools must agree bit-for-bit before CI goes green.
echo "==> exec determinism gate (REPRO_THREADS=2)"
REPRO_THREADS=2 cargo test -q --test exec
echo "==> exec determinism gate (REPRO_THREADS=7)"
REPRO_THREADS=7 cargo test -q --test exec

# Annealed-K smoke: one short end-to-end training with a K schedule
# through the real CLI (per-layer budgets ramping over epochs must parse,
# validate, train, and report) — the K-schedule tentpole's cheapest
# end-to-end proof. Uses the release binary, so it only runs on full
# passes.
if [ "$fast" -eq 0 ]; then
  echo "==> annealed-K CLI smoke (repro train --k linear:3:18)"
  ./target/release/repro train --task energy --policy topk --k "linear:3:18" \
    --epochs 6 --backend native --threads 2 --quiet
fi

# Mixed-precision smoke (ISSUE 8): one short end-to-end training with
# bf16 forward traces + f64 accumulation through the real CLI, plus a
# per-layer q8 override via the --layers grammar — the quantized-trace
# tentpole's cheapest end-to-end proof.
if [ "$fast" -eq 0 ]; then
  echo "==> mixed-precision CLI smoke (repro train --trace bf16 --accum f64)"
  ./target/release/repro train --task energy --policy topk --k 18 \
    --epochs 2 --backend native --threads 2 \
    --trace bf16 --accum f64 --layers "8:tanh:18:q8,1" --quiet
fi

# Observability smoke (ISSUE 6): one traced run through the real CLI —
# the Chrome trace-event dump must be valid JSON with the step phases —
# and one Prometheus scrape against a live `repro serve`. Uses the
# release binary, so it only runs on full passes.
if [ "$fast" -eq 0 ] && command -v python3 >/dev/null 2>&1; then
  echo "==> obs smoke: repro trace (Chrome trace-event dump)"
  ./target/release/repro trace --task energy --policy topk --k 9 \
    --epochs 2 --threads 2 --events 512 --out results/trace_ci.json
  python3 - <<'EOF'
import json
evs = json.load(open("results/trace_ci.json"))
assert isinstance(evs, list) and evs, "trace must be a non-empty event array"
names = {e["name"] for e in evs}
for e in evs:
    assert e["ph"] == "X" and "ts" in e and "dur" in e and "args" in e, e
assert {"fwd", "score", "select", "apply"} <= names, names
print(f"[ci] chrome trace ok: {len(evs)} events, phases {sorted(names)}")
EOF

  echo "==> obs smoke: Prometheus scrape against a live serve"
  ./target/release/repro serve --addr 127.0.0.1:17071 --workers 2 &
  SERVE_PID=$!
  python3 - <<'EOF'
import json, socket, time
for _ in range(100):
    try:
        s = socket.create_connection(("127.0.0.1", 17071), timeout=1)
        break
    except OSError:
        time.sleep(0.1)
else:
    raise SystemExit("serve never came up on 17071")
f = s.makefile("rw")
f.write(json.dumps({"op": "metrics", "format": "prometheus"}) + "\n")
f.flush()
resp = json.loads(f.readline())
assert resp.get("ok"), resp
text = resp["text"]
assert "# TYPE repro_requests_total counter" in text, text[:400]
assert "repro_slots_total" in text, text[:400]
assert "repro_request_latency_seconds_bucket" in text, text[:400]
f.write(json.dumps({"op": "shutdown"}) + "\n")
f.flush()
f.readline()
print("[ci] prometheus scrape ok: %d bytes" % len(text))
EOF
  wait "$SERVE_PID"
fi

# Gradient-fidelity smoke (PR 7): one audited run through the real CLI —
# the per-layer cosine/rel-err/mem-bias table must render with finite
# values — and one live `watch` subscriber against `repro serve`
# receiving at least one streamed epoch frame with audit records.
if [ "$fast" -eq 0 ] && command -v python3 >/dev/null 2>&1; then
  echo "==> audit smoke: repro audit (gradient-fidelity table)"
  mkdir -p results
  ./target/release/repro audit --task energy --policy topk --k 18 \
    --epochs 2 --every every:1 --threads 2 | tee results/audit_ci.txt
  grep -q "gradient fidelity" results/audit_ci.txt
  grep -q "mem bias" results/audit_ci.txt

  echo "==> watch smoke: live epoch streaming against a live serve"
  ./target/release/repro serve --addr 127.0.0.1:17072 --workers 2 &
  SERVE_PID=$!
  python3 - <<'EOF'
import json, socket, time
for _ in range(100):
    try:
        s = socket.create_connection(("127.0.0.1", 17072), timeout=1)
        break
    except OSError:
        time.sleep(0.1)
else:
    raise SystemExit("serve never came up on 17072")
f = s.makefile("rw")

def call(req):
    f.write(json.dumps(req) + "\n")
    f.flush()
    resp = json.loads(f.readline())
    assert resp.get("ok"), resp
    return resp

cfg = call({"op": "ping"})
assert cfg["protocol"] >= 6, cfg
job = call({"op": "submit", "label": "ci-watch", "config": {
    "task": "energy", "policy": "topk", "k": "18", "epochs": 3,
    "lr": 0.01, "seed": 0, "backend": "native", "memory": True,
    "data_scale": 1.0, "audit": "every:1",
}})
jid = job["id"]
frames, cursor = [], 0
deadline = time.time() + 120
while time.time() < deadline:
    r = call({"op": "watch", "id": jid, "cursor": cursor, "wait_ms": 2000})
    batch = r["epochs"]
    frames.extend(batch)
    cursor = r["cursor"]
    if not batch and r["state"] in ("done", "failed", "cancelled"):
        assert r["state"] == "done", r
        break
else:
    raise SystemExit("watched job never finished")
assert len(frames) >= 1, "watch streamed no epochs"
for fr in frames:
    audits = fr.get("audit", [])
    assert audits, fr
    for a in audits:
        assert all(a[k] == a[k] for k in ("cosine", "rel_err", "mem_bias")), a
call({"op": "shutdown"})
print(f"[ci] watch smoke ok: {len(frames)} epoch frames with audit records")
EOF
  wait "$SERVE_PID"
fi

# Resilience smoke (PR 9): a live `repro serve` with fault injection ON
# (worker panics + dropped connections, seed-keyed so the run is
# reproducible) must still land every submitted job in a terminal state
# through client-side retry/reconnect, answer the `health` op, and
# export the v8 rejection/health Prometheus families. The chaos chain's
# cheapest end-to-end proof that the serve tier degrades by failing
# jobs, never by wedging them.
if [ "$fast" -eq 0 ] && command -v python3 >/dev/null 2>&1; then
  echo "==> chaos smoke: faulted serve stays live and leaves no stuck jobs"
  ./target/release/repro serve --addr 127.0.0.1:17073 --workers 2 \
    --queue-cap 8 --faults "seed=7,panic=150,drop=80" &
  SERVE_PID=$!
  python3 - <<'EOF'
import json, socket, time

ADDR = ("127.0.0.1", 17073)

def connect():
    for _ in range(100):
        try:
            return socket.create_connection(ADDR, timeout=5).makefile("rw")
        except OSError:
            time.sleep(0.1)
    raise SystemExit("serve never came up on 17073")

f = connect()

def call(req, retries=20):
    # the server drops connections on purpose: reconnect and retry, and
    # back off briefly on queue_full/rate_limited rejections
    global f
    for attempt in range(retries):
        try:
            f.write(json.dumps(req) + "\n")
            f.flush()
            line = f.readline()
            if not line:
                raise OSError("connection dropped")
            resp = json.loads(line)
        except OSError:
            f = connect()
            continue
        if resp.get("ok"):
            return resp
        if resp.get("reason") in ("queue_full", "rate_limited"):
            time.sleep(resp.get("retry_after_ms", 100) / 1000.0)
            continue
        raise SystemExit(f"unexpected rejection: {resp}")
    raise SystemExit(f"request never succeeded: {req}")

ping = call({"op": "ping"})
assert ping["protocol"] >= 8, ping

cfg = {"task": "energy", "policy": "topk", "k": "18", "epochs": 2,
       "lr": 0.01, "seed": 0, "backend": "native", "memory": True,
       "data_scale": 1.0}
ids = []
for i in range(12):
    c = dict(cfg)
    c["seed"] = i
    ids.append(call({"op": "submit", "label": f"chaos-{i}", "config": c})["id"])

deadline = time.time() + 120
states = {}
while time.time() < deadline:
    states = {i: call({"op": "status", "id": i})["state"] for i in ids}
    if all(s in ("done", "failed") for s in states.values()):
        break
    time.sleep(0.2)
else:
    raise SystemExit(f"stuck jobs after 120s: {states}")
done = sum(1 for s in states.values() if s == "done")
failed = len(ids) - done

health = call({"op": "health", "wait_ms": 2000})
assert health["status"] in ("ok", "degraded"), health
assert health["pool_alive"], health

m = call({"op": "metrics", "format": "prometheus"})["text"]
assert "# TYPE repro_health_status gauge" in m, m[:400]
assert "# TYPE repro_rejected_total counter" in m, m[:400]
assert "repro_connections_open" in m, m[:400]

call({"op": "shutdown"})
print(f"[ci] chaos smoke ok: {done} done + {failed} failed of {len(ids)}, "
      f"none stuck, health={health['status']}")
EOF
  wait "$SERVE_PID"
fi

# Perf smoke: a quick run of the kernels bench so every CI pass leaves
# machine-readable throughput data points (BENCH_2.json: flat engine;
# BENCH_3.json: layer-graph core; BENCH_4.json: wide-layer
# workspace-resident step with the allocations-per-step counter — the
# bench itself asserts the serial steady state performs 0 heap
# allocations; BENCH_5.json: annealed-K step, k ramping mid-run on one
# workspace, also asserted allocation-free; BENCH_6.json: the graph step
# with telemetry ON — per-phase percentiles, still asserted
# allocation-free; BENCH_8.json: the audited step — audit-on vs
# audit-off rows/sec with the K=M re-reduction every few steps, audits
# included in the 0-allocations assertion; BENCH_9.json: the
# mixed-precision trace/accum grid — rows/sec, backward-read trace
# bytes, and fixed-step loss drift per cell, quantized cells asserted
# allocation-free; BENCH_10.json: the serve-burst workload — jobs/sec
# and submit-latency percentiles through submit_with_retry against a
# small admission queue) for the perf trajectory.
echo "==> kernels bench smoke (BENCH_2/3/4/5/6/8/9/10.json)"
BENCH_QUICK=1 cargo bench --bench kernels
test -f BENCH_3.json
test -f BENCH_4.json
test -f BENCH_5.json
test -f BENCH_6.json
test -f BENCH_8.json
test -f BENCH_9.json
test -f BENCH_10.json
echo "BENCH_4.json: $(cat BENCH_4.json | head -c 200)..."
echo "BENCH_5.json: $(cat BENCH_5.json | head -c 200)..."
echo "BENCH_6.json: $(cat BENCH_6.json | head -c 200)..."
echo "BENCH_8.json: $(cat BENCH_8.json | head -c 200)..."
echo "BENCH_9.json: $(cat BENCH_9.json | head -c 200)..."
echo "BENCH_10.json: $(cat BENCH_10.json | head -c 200)..."

# BENCH trajectory (ROADMAP): append this run to the committed bench/
# history and fail on a >15% rows/sec regression vs the recorded
# baseline. BENCH_NO_GATE=1 records without gating (noisy boxes).
echo "==> bench trajectory gate"
if command -v python3 >/dev/null 2>&1; then
  python3 tools/bench_gate.py
else
  echo "python3 not found — bench trajectory skipped"
fi

# -- Opt-in dynamic-analysis lanes (README "Static analysis") ---------------
#
# MIRI=1  — interpret the raw-pointer-heavy unit tests under Miri: the
#           RowBlocks disjoint-block splitter (exec::shard) and the
#           TraceBuf quantized-trace codecs (tensor::quant). Catches UB
#           the type system can't: aliasing violations, OOB, invalid
#           values.
# SAN=1   — ThreadSanitizer over the condvar-driven worker pools
#           (util::pool, exec::pool): data races in the
#           park/wake/generation logic. Needs -Zbuild-std, so the
#           std used by the test is itself instrumented.
#
# Both need a nightly toolchain with the right components; the offline
# CI box may not have one, so a missing toolchain skips loudly instead
# of failing.
if [ "${MIRI:-0}" = "1" ]; then
  if command -v rustup >/dev/null 2>&1 \
    && rustup toolchain list 2>/dev/null | grep -q nightly \
    && rustup component list --toolchain nightly 2>/dev/null \
       | grep -q "miri.*(installed)"; then
    echo "==> MIRI lane: exec::shard + tensor::quant under Miri"
    cargo +nightly miri test --lib -- exec::shard tensor::quant
  else
    echo "############################################################"
    echo "# MIRI=1 requested but no nightly toolchain with the miri  #"
    echo "# component is installed — LANE SKIPPED, NOT PASSED.       #"
    echo "#   rustup toolchain install nightly                       #"
    echo "#   rustup +nightly component add miri                     #"
    echo "############################################################"
  fi
fi

if [ "${SAN:-0}" = "1" ]; then
  if command -v rustup >/dev/null 2>&1 \
    && rustup toolchain list 2>/dev/null | grep -q nightly \
    && rustup component list --toolchain nightly 2>/dev/null \
       | grep -q "rust-src.*(installed)"; then
    host="$(rustc -vV | sed -n 's/^host: //p')"
    echo "==> SAN lane: ThreadSanitizer over util::pool + exec::pool ($host)"
    RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
      cargo +nightly test -Zbuild-std --target "$host" --lib -- \
      util::pool exec::pool
  else
    echo "############################################################"
    echo "# SAN=1 requested but no nightly toolchain with rust-src   #"
    echo "# is installed — LANE SKIPPED, NOT PASSED.                 #"
    echo "#   rustup toolchain install nightly                       #"
    echo "#   rustup +nightly component add rust-src                 #"
    echo "############################################################"
  fi
fi

echo "CI green."
