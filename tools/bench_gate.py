#!/usr/bin/env python3
"""Bench trajectory recorder + regression gate (ROADMAP: BENCH trajectory).

Run from the repo root after `cargo bench --bench kernels` has written
BENCH_2.json ... BENCH_6.json and BENCH_8.json ... BENCH_10.json:

  * appends each record (stamped with UTC time + git rev + host) to
    `bench/history/BENCH_N.jsonl` — the committed machine-readable
    trajectory;
  * compares rows/sec against this machine's own baseline
    `bench/baseline/<host>/BENCH_N.json`; a drop of more than
    REGRESSION_FRAC on any tracked series fails the gate (exit 1)
    unless BENCH_NO_GATE=1 is set (noisy boxes), in which case it only
    warns;
  * initializes a missing baseline from the current record — so the
    first run on ANY machine self-initializes instead of failing
    against some faster box's numbers; commit the generated `bench/`
    contents to pin the CI box's trajectory.

Update a baseline deliberately by deleting its file and re-running.
"""

import json
import os
import platform
import re
import subprocess
import sys
import time

RECORDS = [
    "BENCH_2.json",
    "BENCH_3.json",
    "BENCH_4.json",
    "BENCH_5.json",
    "BENCH_6.json",
    "BENCH_8.json",
    "BENCH_9.json",
    "BENCH_10.json",
]
# keys holding a {"rows_per_sec": ...} object we track; records missing
# a series simply skip it (BENCH_8 carries the audit_* series instead
# of serial/threads4, BENCH_10 carries serve_submit — end-to-end
# jobs/sec of the admission-controlled submit burst)
SERIES = [
    "serial",
    "threads4",
    "audit_off",
    "audit_on",
    "audit_on_threads4",
    "serve_submit",
]
REGRESSION_FRAC = 0.15


def series_items(record):
    """Yield every tracked (series_name, rows_per_sec) pair of a record.

    Top-level SERIES objects cover BENCH_2..8; BENCH_9-style precision
    grids nest their cells under graphs[].cells[], keyed here as
    "<graph>:trace=<t>/accum=<a>" so each precision cell gates
    independently.
    """
    for series in SERIES:
        obj = record.get(series)
        if isinstance(obj, dict) and "rows_per_sec" in obj:
            yield series, obj["rows_per_sec"]
    for g in record.get("graphs") or []:
        label = g.get("graph", "graph")
        for cell in g.get("cells") or []:
            if isinstance(cell, dict) and "rows_per_sec" in cell:
                name = f"{label}:trace={cell.get('trace')}/accum={cell.get('accum')}"
                yield name, cell["rows_per_sec"]


def git_rev():
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True,
            text=True,
            check=True,
        )
        return out.stdout.strip()
    except Exception:
        return "unknown"


def host_key():
    raw = platform.node() or "unknown"
    return re.sub(r"[^A-Za-z0-9._-]", "_", raw)[:64] or "unknown"


def main():
    host = host_key()
    base_dir = os.path.join("bench/baseline", host)
    os.makedirs("bench/history", exist_ok=True)
    os.makedirs(base_dir, exist_ok=True)
    no_gate = os.environ.get("BENCH_NO_GATE") == "1"
    rev = git_rev()
    stamp = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    failures = []
    compared = 0
    recorded = 0

    for name in RECORDS:
        if not os.path.exists(name):
            print(f"[bench-gate] {name} missing — skipped")
            continue
        try:
            with open(name) as f:
                record = json.load(f)
        except (OSError, ValueError) as e:
            # a record the bench just claimed to write but that doesn't
            # parse is a failure, not a skip — a truncated artifact must
            # not silently bypass the regression gate
            print(f"[bench-gate] {name} unreadable: {e}")
            failures.append(f"{name}: unreadable record ({e})")
            continue

        entry = dict(record)
        recorded += 1
        entry["_recorded_at"] = stamp
        entry["_git_rev"] = rev
        entry["_host"] = host
        hist_path = os.path.join("bench/history", name.replace(".json", ".jsonl"))
        with open(hist_path, "a") as f:
            f.write(json.dumps(entry, sort_keys=True) + "\n")

        base_path = os.path.join(base_dir, name)
        if not os.path.exists(base_path):
            with open(base_path, "w") as f:
                json.dump(entry, f, indent=2, sort_keys=True)
                f.write("\n")
            print(
                f"[bench-gate] {name}: baseline for host '{host}' initialized — "
                "commit bench/ to pin it"
            )
            continue

        try:
            with open(base_path) as f:
                baseline = json.load(f)
        except (OSError, ValueError) as e:
            # a corrupt baseline must not wedge the gate forever:
            # re-initialize from the current record and keep recording
            print(f"[bench-gate] {name}: baseline unreadable ({e}) — re-initializing")
            with open(base_path, "w") as f:
                json.dump(entry, f, indent=2, sort_keys=True)
                f.write("\n")
            continue
        base_series = dict(series_items(baseline))
        for series, cur_raw in series_items(record):
            try:
                base = float(base_series[series])
                cur = float(cur_raw)
            except (KeyError, TypeError, ValueError):
                continue
            if base <= 0:
                continue
            compared += 1
            ratio = cur / base
            verdict = "ok"
            if ratio < 1.0 - REGRESSION_FRAC:
                verdict = "REGRESSION"
                failures.append(f"{name}:{series} {cur:.0f} vs baseline {base:.0f} ({ratio:.2f}x)")
            print(
                f"[bench-gate] {name}:{series} {cur:.0f} rows/s vs baseline {base:.0f} "
                f"({ratio:.2f}x) {verdict}"
            )

    if failures:
        msg = "; ".join(failures)
        if no_gate:
            print(f"[bench-gate] WARNING (BENCH_NO_GATE=1): {msg}")
        else:
            print(f"[bench-gate] FAILED: {msg}")
            print("[bench-gate] (set BENCH_NO_GATE=1 to record without gating)")
            sys.exit(1)
    if recorded and compared == 0:
        # freshly-initialized (or series-less) baselines mean this run
        # gated NOTHING — say so loudly instead of printing a quiet
        # success that reads like a passed regression check
        print("[bench-gate] " + "!" * 64)
        print(
            f"[bench-gate] !! NO BASELINE COMPARISONS RAN on host '{host}': "
            f"{recorded} record(s) written, 0 series gated."
        )
        print(
            "[bench-gate] !! This run initialized baselines only — commit the "
            "generated bench/ directory to pin this box's trajectory, or "
            "every future run keeps passing vacuously."
        )
        print("[bench-gate] " + "!" * 64)
    print("[bench-gate] trajectory recorded")


if __name__ == "__main__":
    main()
